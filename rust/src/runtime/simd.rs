//! Explicit 8-lane f32 SIMD layer under the exec tiers.
//!
//! One generic kernel body per operation, instantiated for three backends:
//! AVX2+FMA (`__m256`) on x86_64, NEON (2 × `float32x4_t`) on aarch64, and
//! a plain `[f32; 8]` scalar fallback everywhere. The backend is picked
//! **once per process** by runtime feature detection (cached in an atomic,
//! resolved on first use — i.e. at pool startup for the CLI paths) and can
//! be forced off with `MINITENSOR_SIMD=off` (or `0` / `false` / `scalar`).
//!
//! # Determinism contract
//!
//! Every lane operation is defined so the three backends produce the same
//! bits: arithmetic (`+ - * /`, sqrt) is IEEE-exact on all paths; `max` /
//! `min` are the branchless `if a > b { a } else { b }` select that x86
//! `maxps` implements (see [`max_s`]); fused multiply-add is the correctly
//! rounded `f32::mul_add` on the scalar path and a hardware FMA on the
//! vector paths (both correctly rounded, hence bit-equal); and the
//! transcendental kernels ([`vexp`] mirroring `kernels::fast_exp`,
//! [`vtanh`] mirroring [`tanh_s`]) evaluate the *same* polynomial with the
//! same fixed association per lane. Reductions use a fixed 8-accumulator
//! tree with a sequential lane fold and a scalar tail, identical on every
//! backend. SIMD-on and SIMD-off are therefore bitwise-equal **by
//! construction**, not merely by test — and since lanes never interact in
//! map kernels, the equality also holds under any chunk partition, which
//! is what keeps the 1-vs-N-thread bitwise CI contract intact.
//!
//! Accuracy: the polynomial `exp` kernel keeps `fast_exp`'s ≈4e-6 max
//! relative error (~32 ULP worst case); the Cephes-style `tanh` kernel is
//! ~2 ULP inside |x| < 0.625 and inherits the `exp` error above it. Both
//! are far below the 1e-5 tolerances of every consumer (softmax, CE,
//! GELU).

#![allow(unused_unsafe)] // intrinsics are safe-in-target-feature on newer toolchains

use std::sync::atomic::{AtomicUsize, Ordering};

/// Vector width in f32 lanes (fixed: AVX2 = 1×8, NEON = 2×4, scalar = 8).
pub const LANES: usize = 8;

const UNRESOLVED: usize = 0;
const P_AVX2: usize = 1;
const P_NEON: usize = 2;
const P_SCALAR: usize = 3;

/// Which instruction family the block kernels run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdPath {
    /// x86_64 AVX2 + FMA (8 × f32 per register).
    Avx2,
    /// aarch64 NEON (2 × 4 f32 registers per 8-lane block).
    Neon,
    /// Portable `[f32; 8]` blocks — also the `MINITENSOR_SIMD=off` path.
    Scalar,
}

impl SimdPath {
    /// Short name for reports and bench JSON (`avx2` / `neon` / `scalar`).
    pub fn name(self) -> &'static str {
        match self {
            SimdPath::Avx2 => "avx2",
            SimdPath::Neon => "neon",
            SimdPath::Scalar => "scalar",
        }
    }

    /// True when a real vector ISA (not the scalar fallback) is active.
    pub fn is_vector(self) -> bool {
        !matches!(self, SimdPath::Scalar)
    }
}

/// Resolved path, `UNRESOLVED` until first use.
static PATH: AtomicUsize = AtomicUsize::new(UNRESOLVED);

#[cfg(target_arch = "x86_64")]
fn detect() -> usize {
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        P_AVX2
    } else {
        P_SCALAR
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> usize {
    P_NEON // NEON is baseline on aarch64
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> usize {
    P_SCALAR
}

fn env_enabled() -> bool {
    match std::env::var("MINITENSOR_SIMD") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "off" | "0" | "false" | "scalar"
        ),
        Err(_) => true,
    }
}

fn decode(v: usize) -> SimdPath {
    match v {
        P_AVX2 => SimdPath::Avx2,
        P_NEON => SimdPath::Neon,
        _ => SimdPath::Scalar,
    }
}

/// The active dispatch path. Detected once (honouring `MINITENSOR_SIMD`),
/// then cached for the life of the process; bit-equal outputs on every
/// path make a mid-run override via [`set_simd_enabled`] observable only
/// in speed, never in results.
pub fn path() -> SimdPath {
    let v = PATH.load(Ordering::Relaxed);
    if v != UNRESOLVED {
        return decode(v);
    }
    let want = if env_enabled() { detect() } else { P_SCALAR };
    // First resolver wins; concurrent resolvers compute the same value.
    let _ = PATH.compare_exchange(UNRESOLVED, want, Ordering::Relaxed, Ordering::Relaxed);
    decode(PATH.load(Ordering::Relaxed))
}

/// Force the vector path on (re-detect) or off (scalar blocks). Test and
/// bench hook — the env knob only applies at first resolution.
pub fn set_simd_enabled(on: bool) {
    PATH.store(if on { detect() } else { P_SCALAR }, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Op enums + scalar twins
// ---------------------------------------------------------------------------

/// Binary elementwise op kinds the block kernels understand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    /// `if a > b { a } else { b }` — see [`max_s`].
    Max,
    /// `if a < b { a } else { b }` — see [`min_s`].
    Min,
}

/// Unary elementwise op kinds the block kernels understand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum UnOp {
    Neg,
    Relu,
    /// `kernels::fast_exp` semantics (polynomial, clamped).
    Exp,
    Sqrt,
    Square,
    Abs,
    Sigmoid,
    /// [`tanh_s`] semantics (Cephes polynomial + `fast_exp` tail).
    Tanh,
    Gelu,
    AddScalar(f32),
    MulScalar(f32),
    Clamp(f32, f32),
    LeakyRelu(f32),
}

/// Deterministic branchless max: `if a > b { a } else { b }`.
///
/// This is exactly what x86 `maxps` computes (unordered compares return
/// the second operand), so the scalar twin and the AVX2 path agree on
/// every input including NaNs; the NEON path uses an explicit
/// compare+select to match. Unlike `f32::max`, a NaN in `b` propagates —
/// identical to `f32::max` whenever `b` is a non-NaN constant (e.g. ReLU).
#[inline(always)]
pub fn max_s(a: f32, b: f32) -> f32 {
    if a > b {
        a
    } else {
        b
    }
}

/// Deterministic branchless min: `if a < b { a } else { b }` (x86 `minps`).
#[inline(always)]
pub fn min_s(a: f32, b: f32) -> f32 {
    if a < b {
        a
    } else {
        b
    }
}

/// Scalar tanh with the same polynomial split the vector kernel uses:
/// Cephes rational approximation for |x| < 0.625, `1 − 2/(e^{2|x|}+1)`
/// via `fast_exp` above it. ~2 ULP in the polynomial range.
#[inline(always)]
pub fn tanh_s(x: f32) -> f32 {
    use crate::ops::kernels::fast_exp;
    let z = x.abs();
    if 0.625 > z {
        let s = x * x;
        let p = -5.704_988_7e-3_f32;
        let p = p * s + 2.063_908_9e-2;
        let p = p * s + -5.373_971_6e-2;
        let p = p * s + 1.333_144_2e-1;
        let p = p * s + -3.333_328_2e-1;
        x + x * s * p
    } else {
        let e = fast_exp(z + z);
        let r = 1.0 - 2.0 / (e + 1.0);
        if 0.0 > x {
            -r
        } else {
            r
        }
    }
}

/// Per-lane semantics of `op` — the tail / strided / off-path twin of the
/// vector binary kernels. Every execution path funnels through these
/// definitions, which is what keeps them bitwise-interchangeable.
#[inline(always)]
pub fn bin_s(op: BinOp, a: f32, b: f32) -> f32 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        BinOp::Max => max_s(a, b),
        BinOp::Min => min_s(a, b),
    }
}

/// Per-lane semantics of `op` — the tail / strided / off-path twin of the
/// vector unary kernels.
#[inline(always)]
pub fn un_s(op: UnOp, v: f32) -> f32 {
    match op {
        UnOp::Neg => -v,
        UnOp::Relu => max_s(v, 0.0),
        UnOp::Exp => crate::ops::kernels::fast_exp(v),
        UnOp::Sqrt => v.sqrt(),
        UnOp::Square => v * v,
        UnOp::Abs => v.abs(),
        UnOp::Sigmoid => crate::ops::unary::sigmoid_scalar(v),
        UnOp::Tanh => tanh_s(v),
        UnOp::Gelu => crate::ops::unary::gelu_scalar(v),
        UnOp::AddScalar(c) => v + c,
        UnOp::MulScalar(c) => v * c,
        UnOp::Clamp(lo, hi) => v.clamp(lo, hi),
        UnOp::LeakyRelu(a) => {
            if v > 0.0 {
                v
            } else {
                a * v
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The 8-lane vector abstraction
// ---------------------------------------------------------------------------

/// 8 × f32 vector operations. Every lane op matches the scalar twins above
/// exactly (`max` is [`max_s`], `mul_add` is `f32::mul_add`, compares are
/// ordered-greater-than), which makes SIMD-on and SIMD-off bit-identical
/// by construction. All methods are `unsafe` for uniformity; only
/// `load`/`store` carry real obligations (8 valid f32 slots at `p`).
trait Simd8: Copy {
    type F: Copy;
    unsafe fn load(p: *const f32) -> Self::F;
    unsafe fn store(p: *mut f32, v: Self::F);
    unsafe fn splat(x: f32) -> Self::F;
    unsafe fn add(a: Self::F, b: Self::F) -> Self::F;
    unsafe fn sub(a: Self::F, b: Self::F) -> Self::F;
    unsafe fn mul(a: Self::F, b: Self::F) -> Self::F;
    unsafe fn div(a: Self::F, b: Self::F) -> Self::F;
    /// `if a > b { a } else { b }` per lane (NaN ⇒ `b`), i.e. [`max_s`].
    unsafe fn max(a: Self::F, b: Self::F) -> Self::F;
    /// `if a < b { a } else { b }` per lane (NaN ⇒ `b`), i.e. [`min_s`].
    unsafe fn min(a: Self::F, b: Self::F) -> Self::F;
    /// Correctly rounded `a*b + c` (`f32::mul_add` / hardware FMA).
    unsafe fn mul_add(a: Self::F, b: Self::F, c: Self::F) -> Self::F;
    unsafe fn floor(a: Self::F) -> Self::F;
    unsafe fn sqrt(a: Self::F) -> Self::F;
    unsafe fn abs(a: Self::F) -> Self::F;
    unsafe fn neg(a: Self::F) -> Self::F;
    /// Per-lane `if a > b { x } else { y }` (ordered compare, NaN ⇒ `y`).
    unsafe fn select_gt(a: Self::F, b: Self::F, x: Self::F, y: Self::F) -> Self::F;
    /// Per-lane `if c != 0.0 { x } else { y }` (NaN counts as ≠ 0).
    unsafe fn select_neq0(c: Self::F, x: Self::F, y: Self::F) -> Self::F;
    /// `2^k` for integral-valued lanes `k` via the exponent-bit trick —
    /// mirrors `((k as i32 + 127) as u32) << 23` in `fast_exp`.
    unsafe fn exp2i(k: Self::F) -> Self::F;
    unsafe fn to_array(v: Self::F) -> [f32; LANES];
}

/// Portable backend: `[f32; 8]` blocks, lane ops written against the same
/// semantic twins the tails use. This is the `MINITENSOR_SIMD=off` path.
mod scalar8 {
    use super::{max_s, min_s, Simd8, LANES};

    #[derive(Clone, Copy)]
    pub(super) struct Scalar8;

    #[inline(always)]
    fn map2(a: [f32; LANES], b: [f32; LANES], f: impl Fn(f32, f32) -> f32) -> [f32; LANES] {
        let mut o = [0.0f32; LANES];
        for i in 0..LANES {
            o[i] = f(a[i], b[i]);
        }
        o
    }

    #[inline(always)]
    fn map1(a: [f32; LANES], f: impl Fn(f32) -> f32) -> [f32; LANES] {
        let mut o = [0.0f32; LANES];
        for i in 0..LANES {
            o[i] = f(a[i]);
        }
        o
    }

    impl Simd8 for Scalar8 {
        type F = [f32; LANES];
        #[inline(always)]
        unsafe fn load(p: *const f32) -> [f32; LANES] {
            unsafe { *(p as *const [f32; LANES]) }
        }
        #[inline(always)]
        unsafe fn store(p: *mut f32, v: [f32; LANES]) {
            unsafe {
                *(p as *mut [f32; LANES]) = v;
            }
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> [f32; LANES] {
            [x; LANES]
        }
        #[inline(always)]
        unsafe fn add(a: [f32; LANES], b: [f32; LANES]) -> [f32; LANES] {
            map2(a, b, |x, y| x + y)
        }
        #[inline(always)]
        unsafe fn sub(a: [f32; LANES], b: [f32; LANES]) -> [f32; LANES] {
            map2(a, b, |x, y| x - y)
        }
        #[inline(always)]
        unsafe fn mul(a: [f32; LANES], b: [f32; LANES]) -> [f32; LANES] {
            map2(a, b, |x, y| x * y)
        }
        #[inline(always)]
        unsafe fn div(a: [f32; LANES], b: [f32; LANES]) -> [f32; LANES] {
            map2(a, b, |x, y| x / y)
        }
        #[inline(always)]
        unsafe fn max(a: [f32; LANES], b: [f32; LANES]) -> [f32; LANES] {
            map2(a, b, max_s)
        }
        #[inline(always)]
        unsafe fn min(a: [f32; LANES], b: [f32; LANES]) -> [f32; LANES] {
            map2(a, b, min_s)
        }
        #[inline(always)]
        unsafe fn mul_add(a: [f32; LANES], b: [f32; LANES], c: [f32; LANES]) -> [f32; LANES] {
            let mut o = [0.0f32; LANES];
            for i in 0..LANES {
                o[i] = a[i].mul_add(b[i], c[i]);
            }
            o
        }
        #[inline(always)]
        unsafe fn floor(a: [f32; LANES]) -> [f32; LANES] {
            map1(a, f32::floor)
        }
        #[inline(always)]
        unsafe fn sqrt(a: [f32; LANES]) -> [f32; LANES] {
            map1(a, f32::sqrt)
        }
        #[inline(always)]
        unsafe fn abs(a: [f32; LANES]) -> [f32; LANES] {
            map1(a, f32::abs)
        }
        #[inline(always)]
        unsafe fn neg(a: [f32; LANES]) -> [f32; LANES] {
            map1(a, |x| -x)
        }
        #[inline(always)]
        unsafe fn select_gt(
            a: [f32; LANES],
            b: [f32; LANES],
            x: [f32; LANES],
            y: [f32; LANES],
        ) -> [f32; LANES] {
            let mut o = [0.0f32; LANES];
            for i in 0..LANES {
                o[i] = if a[i] > b[i] { x[i] } else { y[i] };
            }
            o
        }
        #[inline(always)]
        unsafe fn select_neq0(
            c: [f32; LANES],
            x: [f32; LANES],
            y: [f32; LANES],
        ) -> [f32; LANES] {
            let mut o = [0.0f32; LANES];
            for i in 0..LANES {
                o[i] = if c[i] != 0.0 { x[i] } else { y[i] };
            }
            o
        }
        #[inline(always)]
        unsafe fn exp2i(k: [f32; LANES]) -> [f32; LANES] {
            map1(k, |v| f32::from_bits(((v as i32 + 127) as u32) << 23))
        }
        #[inline(always)]
        unsafe fn to_array(v: [f32; LANES]) -> [f32; LANES] {
            v
        }
    }
}

// ---------------------------------------------------------------------------
// Generic kernel bodies
// ---------------------------------------------------------------------------
//
// Each body is monomorphized once per backend inside the `#[target_feature]`
// entry wrappers below; with every trait method `#[inline(always)]` the
// compiler sees straight-line intrinsics and emits real vector code.

#[inline(always)]
unsafe fn apply_bin<S: Simd8>(op: BinOp, a: S::F, b: S::F) -> S::F {
    unsafe {
        match op {
            BinOp::Add => S::add(a, b),
            BinOp::Sub => S::sub(a, b),
            BinOp::Mul => S::mul(a, b),
            BinOp::Div => S::div(a, b),
            BinOp::Max => S::max(a, b),
            BinOp::Min => S::min(a, b),
        }
    }
}

/// Vector `fast_exp`: mirrors `kernels::fast_exp` lane-for-lane — same
/// clamp (f32::clamp association), same `k + f` split, same Horner chain
/// (plain mul+add, *not* FMA, to keep the scalar twin's rounding), same
/// exponent-bit scale.
#[inline(always)]
unsafe fn vexp<S: Simd8>(x: S::F) -> S::F {
    unsafe {
        let lo = S::splat(-87.0);
        let hi = S::splat(88.0);
        // f32::clamp: `if x < lo { lo } else if x > hi { hi } else { x }`.
        let x = S::select_gt(lo, x, lo, S::select_gt(x, hi, hi, x));
        let t = S::mul(x, S::splat(std::f32::consts::LOG2_E));
        let k = S::floor(t);
        let f = S::sub(t, k);
        let p = S::splat(1.525_273_4e-5);
        let p = S::add(S::splat(1.540_353e-4), S::mul(f, p));
        let p = S::add(S::splat(0.001_333_355_8), S::mul(f, p));
        let p = S::add(S::splat(0.009_618_129), S::mul(f, p));
        let p = S::add(S::splat(0.055_504_11), S::mul(f, p));
        let p = S::add(S::splat(0.240_226_51), S::mul(f, p));
        let p = S::add(S::splat(0.693_147_18), S::mul(f, p));
        let p = S::add(S::splat(1.0), S::mul(f, p));
        S::mul(S::exp2i(k), p)
    }
}

/// Vector tanh mirroring [`tanh_s`]: both branches computed, then blended
/// on the same `0.625 > |x|` predicate the scalar twin branches on.
#[inline(always)]
unsafe fn vtanh<S: Simd8>(x: S::F) -> S::F {
    unsafe {
        let z = S::abs(x);
        let s = S::mul(x, x);
        let p = S::splat(-5.704_988_7e-3);
        let p = S::add(S::mul(p, s), S::splat(2.063_908_9e-2));
        let p = S::add(S::mul(p, s), S::splat(-5.373_971_6e-2));
        let p = S::add(S::mul(p, s), S::splat(1.333_144_2e-1));
        let p = S::add(S::mul(p, s), S::splat(-3.333_328_2e-1));
        let poly = S::add(x, S::mul(S::mul(x, s), p));
        let e = vexp::<S>(S::add(z, z));
        let r = S::sub(
            S::splat(1.0),
            S::div(S::splat(2.0), S::add(e, S::splat(1.0))),
        );
        let expb = S::select_gt(S::splat(0.0), x, S::neg(r), r);
        S::select_gt(S::splat(0.625), z, poly, expb)
    }
}

/// Vector sigmoid mirroring `unary::sigmoid_scalar`: both stable branches
/// computed, blended on the scalar twin's `x >= 0` predicate.
#[inline(always)]
unsafe fn vsigmoid<S: Simd8>(x: S::F) -> S::F {
    unsafe {
        let one = S::splat(1.0);
        let pos = S::div(one, S::add(one, vexp::<S>(S::neg(x))));
        let e = vexp::<S>(x);
        let neg = S::div(e, S::add(one, e));
        // x >= 0 ⟺ !(0 > x): pick `neg` where 0 > x, else `pos`.
        S::select_gt(S::splat(0.0), x, neg, pos)
    }
}

/// Vector GELU mirroring `unary::gelu_scalar` (tanh approximation) with
/// the identical association of every product.
#[inline(always)]
unsafe fn vgelu<S: Simd8>(x: S::F) -> S::F {
    unsafe {
        // 0.5 * x * (1.0 + tanh(C * (x + 0.044715 * x * x * x)))
        let x3 = S::mul(S::mul(S::mul(S::splat(0.044715), x), x), x);
        let u = S::mul(
            S::splat(crate::ops::unary::SQRT_2_OVER_PI),
            S::add(x, x3),
        );
        let t = vtanh::<S>(u);
        S::mul(S::mul(S::splat(0.5), x), S::add(S::splat(1.0), t))
    }
}

#[inline(always)]
unsafe fn apply_un<S: Simd8>(op: UnOp, v: S::F) -> S::F {
    unsafe {
        match op {
            UnOp::Neg => S::neg(v),
            UnOp::Relu => S::max(v, S::splat(0.0)),
            UnOp::Exp => vexp::<S>(v),
            UnOp::Sqrt => S::sqrt(v),
            UnOp::Square => S::mul(v, v),
            UnOp::Abs => S::abs(v),
            UnOp::Sigmoid => vsigmoid::<S>(v),
            UnOp::Tanh => vtanh::<S>(v),
            UnOp::Gelu => vgelu::<S>(v),
            UnOp::AddScalar(c) => S::add(v, S::splat(c)),
            UnOp::MulScalar(c) => S::mul(v, S::splat(c)),
            UnOp::Clamp(lo, hi) => {
                let l = S::splat(lo);
                let h = S::splat(hi);
                // f32::clamp: `if v < lo { lo } else if v > hi { hi } else { v }`.
                S::select_gt(l, v, l, S::select_gt(v, h, h, v))
            }
            UnOp::LeakyRelu(a) => S::select_gt(v, S::splat(0.0), v, S::mul(S::splat(a), v)),
        }
    }
}

#[inline(always)]
unsafe fn un_to_impl<S: Simd8>(op: UnOp, src: &[f32], dst: *mut f32) {
    let n = src.len();
    let mut i = 0;
    unsafe {
        while i + LANES <= n {
            S::store(dst.add(i), apply_un::<S>(op, S::load(src.as_ptr().add(i))));
            i += LANES;
        }
        while i < n {
            *dst.add(i) = un_s(op, src[i]);
            i += 1;
        }
    }
}

#[inline(always)]
unsafe fn un_ip_impl<S: Simd8>(op: UnOp, dst: &mut [f32]) {
    let n = dst.len();
    let p = dst.as_mut_ptr();
    let mut i = 0;
    unsafe {
        while i + LANES <= n {
            S::store(p.add(i), apply_un::<S>(op, S::load(p.add(i))));
            i += LANES;
        }
        while i < n {
            *p.add(i) = un_s(op, *p.add(i));
            i += 1;
        }
    }
}

#[inline(always)]
unsafe fn bin_to_impl<S: Simd8>(op: BinOp, a: &[f32], b: &[f32], dst: *mut f32) {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut i = 0;
    unsafe {
        while i + LANES <= n {
            let x = S::load(a.as_ptr().add(i));
            let y = S::load(b.as_ptr().add(i));
            S::store(dst.add(i), apply_bin::<S>(op, x, y));
            i += LANES;
        }
        while i < n {
            *dst.add(i) = bin_s(op, a[i], b[i]);
            i += 1;
        }
    }
}

#[inline(always)]
unsafe fn bin_ip_impl<S: Simd8>(op: BinOp, dst: &mut [f32], rhs: &[f32]) {
    debug_assert_eq!(dst.len(), rhs.len());
    let n = dst.len();
    let p = dst.as_mut_ptr();
    let mut i = 0;
    unsafe {
        while i + LANES <= n {
            let x = S::load(p.add(i));
            let y = S::load(rhs.as_ptr().add(i));
            S::store(p.add(i), apply_bin::<S>(op, x, y));
            i += LANES;
        }
        while i < n {
            *p.add(i) = bin_s(op, *p.add(i), rhs[i]);
            i += 1;
        }
    }
}

#[inline(always)]
unsafe fn select_to_impl<S: Simd8>(c: &[f32], a: &[f32], b: &[f32], dst: *mut f32) {
    debug_assert_eq!(c.len(), a.len());
    debug_assert_eq!(c.len(), b.len());
    let n = c.len();
    let mut i = 0;
    unsafe {
        while i + LANES <= n {
            let cv = S::load(c.as_ptr().add(i));
            let av = S::load(a.as_ptr().add(i));
            let bv = S::load(b.as_ptr().add(i));
            S::store(dst.add(i), S::select_neq0(cv, av, bv));
            i += LANES;
        }
        while i < n {
            *dst.add(i) = crate::ops::kernels::select(c[i], a[i], b[i]);
            i += 1;
        }
    }
}

/// In-place select: `dst` holds the condition and receives the result.
#[inline(always)]
unsafe fn select_ip_impl<S: Simd8>(dst: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    let n = dst.len();
    let p = dst.as_mut_ptr();
    let mut i = 0;
    unsafe {
        while i + LANES <= n {
            let cv = S::load(p.add(i));
            let av = S::load(a.as_ptr().add(i));
            let bv = S::load(b.as_ptr().add(i));
            S::store(p.add(i), S::select_neq0(cv, av, bv));
            i += LANES;
        }
        while i < n {
            *p.add(i) = crate::ops::kernels::select(*p.add(i), a[i], b[i]);
            i += 1;
        }
    }
}

/// Sum with the exact fold of `kernels::sum`: one 8-lane accumulator over
/// whole blocks (lane j accumulates elements ≡ j mod 8), a scalar tail,
/// then `lanes.sum() + tail` — bit-identical to the seed scalar kernel.
#[inline(always)]
unsafe fn sum_impl<S: Simd8>(xs: &[f32]) -> f32 {
    let n = xs.len();
    let mut i = 0;
    unsafe {
        let mut vacc = S::splat(0.0);
        while i + LANES <= n {
            vacc = S::add(vacc, S::load(xs.as_ptr().add(i)));
            i += LANES;
        }
        let mut tail = 0.0f32;
        for &v in &xs[i..] {
            tail += v;
        }
        S::to_array(vacc).iter().sum::<f32>() + tail
    }
}

/// Dot product with the exact fold of `kernels::dot` (plain mul+add per
/// lane — not FMA — so the bits match the seed scalar kernel).
#[inline(always)]
unsafe fn dot_impl<S: Simd8>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut i = 0;
    unsafe {
        let mut vacc = S::splat(0.0);
        while i + LANES <= n {
            let x = S::load(a.as_ptr().add(i));
            let y = S::load(b.as_ptr().add(i));
            vacc = S::add(vacc, S::mul(x, y));
            i += LANES;
        }
        let mut tail = 0.0f32;
        while i < n {
            tail += a[i] * b[i];
            i += 1;
        }
        S::to_array(vacc).iter().sum::<f32>() + tail
    }
}

/// Max of `xs[i] * scale` with a fixed 8-lane fold: blockwise lane maxes,
/// sequential lane fold, scalar tail. `scale = 1.0` is the plain max
/// (`v * 1.0` is bit-exact), which is what keeps the fused scaled-softmax
/// prologue bitwise-equal to `mul_scalar` + softmax.
#[inline(always)]
unsafe fn max_scaled_impl<S: Simd8>(xs: &[f32], scale: f32) -> f32 {
    let n = xs.len();
    let mut i = 0;
    unsafe {
        let sv = S::splat(scale);
        let mut vacc = S::splat(f32::NEG_INFINITY);
        while i + LANES <= n {
            vacc = S::max(vacc, S::mul(S::load(xs.as_ptr().add(i)), sv));
            i += LANES;
        }
        let mut m = f32::NEG_INFINITY;
        for &a in S::to_array(vacc).iter() {
            m = max_s(m, a);
        }
        while i < n {
            m = max_s(m, xs[i] * scale);
            i += 1;
        }
        m
    }
}

/// Min with the same fixed 8-lane fold shape as [`max_scaled_impl`].
#[inline(always)]
unsafe fn min_impl<S: Simd8>(xs: &[f32]) -> f32 {
    let n = xs.len();
    let mut i = 0;
    unsafe {
        let mut vacc = S::splat(f32::INFINITY);
        while i + LANES <= n {
            vacc = S::min(vacc, S::load(xs.as_ptr().add(i)));
            i += LANES;
        }
        let mut m = f32::INFINITY;
        for &a in S::to_array(vacc).iter() {
            m = min_s(m, a);
        }
        while i < n {
            m = min_s(m, xs[i]);
            i += 1;
        }
        m
    }
}

/// `Σ fast_exp(v − m)` with the fixed 8-lane fold (logsumexp inner sum).
#[inline(always)]
unsafe fn sum_exp_sub_impl<S: Simd8>(xs: &[f32], m: f32) -> f32 {
    let n = xs.len();
    let mut i = 0;
    unsafe {
        let mv = S::splat(m);
        let mut vacc = S::splat(0.0);
        while i + LANES <= n {
            vacc = S::add(vacc, vexp::<S>(S::sub(S::load(xs.as_ptr().add(i)), mv)));
            i += LANES;
        }
        let mut tail = 0.0f32;
        while i < n {
            tail += crate::ops::kernels::fast_exp(xs[i] - m);
            i += 1;
        }
        S::to_array(vacc).iter().sum::<f32>() + tail
    }
}

/// Row exp pass: `dst[i] = fast_exp(src[i] * scale − m)`. `scale = 1.0`
/// is the plain shifted-exp row (bit-exact, see [`max_scaled_impl`]).
#[inline(always)]
unsafe fn exp_scaled_sub_to_impl<S: Simd8>(src: &[f32], scale: f32, m: f32, dst: *mut f32) {
    let n = src.len();
    let mut i = 0;
    unsafe {
        let sv = S::splat(scale);
        let mv = S::splat(m);
        while i + LANES <= n {
            let v = S::load(src.as_ptr().add(i));
            S::store(dst.add(i), vexp::<S>(S::sub(S::mul(v, sv), mv)));
            i += LANES;
        }
        while i < n {
            *dst.add(i) = crate::ops::kernels::fast_exp(src[i] * scale - m);
            i += 1;
        }
    }
}

/// `dst[i] += s * x[i]` with the exact association of `kernels::axpy`
/// (plain mul+add — bit-identical to the seed scalar kernel).
#[inline(always)]
unsafe fn axpy_impl<S: Simd8>(s: f32, x: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(x.len(), dst.len());
    let n = dst.len();
    let p = dst.as_mut_ptr();
    let mut i = 0;
    unsafe {
        let sv = S::splat(s);
        while i + LANES <= n {
            let o = S::load(p.add(i));
            let v = S::load(x.as_ptr().add(i));
            S::store(p.add(i), S::add(o, S::mul(sv, v)));
            i += LANES;
        }
        while i < n {
            *p.add(i) += s * x[i];
            i += 1;
        }
    }
}

/// SGEMM micro-kernel: a full 4×16 register tile, `C += Aᵖ·Bᵖ` over a
/// packed-A column stream (MR-interleaved, 4 floats per k step) and a
/// packed-B row block (16 contiguous floats per k step, rows `ldb`
/// apart — the row stride of the caller's packed kc×nc block). 8
/// accumulator vectors + 2 B vectors + 1 A broadcast stay in registers;
/// FMA on the vector paths, `f32::mul_add` on the scalar path (both
/// correctly rounded ⇒ bit-equal).
#[inline(always)]
unsafe fn sgemm_micro_4x16_impl<S: Simd8>(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    ldb: usize,
    c: *mut f32,
    ldc: usize,
) {
    unsafe {
        let mut acc00 = S::splat(0.0);
        let mut acc01 = S::splat(0.0);
        let mut acc10 = S::splat(0.0);
        let mut acc11 = S::splat(0.0);
        let mut acc20 = S::splat(0.0);
        let mut acc21 = S::splat(0.0);
        let mut acc30 = S::splat(0.0);
        let mut acc31 = S::splat(0.0);
        let apreq = ap.as_ptr();
        let bpreq = bp.as_ptr();
        for p in 0..kc {
            let b0 = S::load(bpreq.add(p * ldb));
            let b1 = S::load(bpreq.add(p * ldb + 8));
            let a0 = S::splat(*apreq.add(p * 4));
            acc00 = S::mul_add(a0, b0, acc00);
            acc01 = S::mul_add(a0, b1, acc01);
            let a1 = S::splat(*apreq.add(p * 4 + 1));
            acc10 = S::mul_add(a1, b0, acc10);
            acc11 = S::mul_add(a1, b1, acc11);
            let a2 = S::splat(*apreq.add(p * 4 + 2));
            acc20 = S::mul_add(a2, b0, acc20);
            acc21 = S::mul_add(a2, b1, acc21);
            let a3 = S::splat(*apreq.add(p * 4 + 3));
            acc30 = S::mul_add(a3, b0, acc30);
            acc31 = S::mul_add(a3, b1, acc31);
        }
        let rows = [
            (acc00, acc01),
            (acc10, acc11),
            (acc20, acc21),
            (acc30, acc31),
        ];
        for (i, (lo, hi)) in rows.iter().enumerate() {
            let crow = c.add(i * ldc);
            S::store(crow, S::add(S::load(crow), *lo));
            S::store(crow.add(8), S::add(S::load(crow.add(8)), *hi));
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 backend (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{Simd8, LANES};
    use std::arch::x86_64::*;

    #[derive(Clone, Copy)]
    pub(super) struct Avx2;

    impl Simd8 for Avx2 {
        type F = __m256;
        #[inline(always)]
        unsafe fn load(p: *const f32) -> __m256 {
            unsafe { _mm256_loadu_ps(p) }
        }
        #[inline(always)]
        unsafe fn store(p: *mut f32, v: __m256) {
            unsafe { _mm256_storeu_ps(p, v) }
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> __m256 {
            unsafe { _mm256_set1_ps(x) }
        }
        #[inline(always)]
        unsafe fn add(a: __m256, b: __m256) -> __m256 {
            unsafe { _mm256_add_ps(a, b) }
        }
        #[inline(always)]
        unsafe fn sub(a: __m256, b: __m256) -> __m256 {
            unsafe { _mm256_sub_ps(a, b) }
        }
        #[inline(always)]
        unsafe fn mul(a: __m256, b: __m256) -> __m256 {
            unsafe { _mm256_mul_ps(a, b) }
        }
        #[inline(always)]
        unsafe fn div(a: __m256, b: __m256) -> __m256 {
            unsafe { _mm256_div_ps(a, b) }
        }
        #[inline(always)]
        unsafe fn max(a: __m256, b: __m256) -> __m256 {
            // maxps is exactly `if a > b { a } else { b }` (NaN ⇒ b).
            unsafe { _mm256_max_ps(a, b) }
        }
        #[inline(always)]
        unsafe fn min(a: __m256, b: __m256) -> __m256 {
            unsafe { _mm256_min_ps(a, b) }
        }
        #[inline(always)]
        unsafe fn mul_add(a: __m256, b: __m256, c: __m256) -> __m256 {
            unsafe { _mm256_fmadd_ps(a, b, c) }
        }
        #[inline(always)]
        unsafe fn floor(a: __m256) -> __m256 {
            unsafe { _mm256_floor_ps(a) }
        }
        #[inline(always)]
        unsafe fn sqrt(a: __m256) -> __m256 {
            unsafe { _mm256_sqrt_ps(a) }
        }
        #[inline(always)]
        unsafe fn abs(a: __m256) -> __m256 {
            unsafe { _mm256_andnot_ps(_mm256_set1_ps(-0.0), a) }
        }
        #[inline(always)]
        unsafe fn neg(a: __m256) -> __m256 {
            unsafe { _mm256_xor_ps(_mm256_set1_ps(-0.0), a) }
        }
        #[inline(always)]
        unsafe fn select_gt(a: __m256, b: __m256, x: __m256, y: __m256) -> __m256 {
            unsafe {
                let m = _mm256_cmp_ps::<_CMP_GT_OQ>(a, b);
                _mm256_blendv_ps(y, x, m)
            }
        }
        #[inline(always)]
        unsafe fn select_neq0(c: __m256, x: __m256, y: __m256) -> __m256 {
            unsafe {
                let m = _mm256_cmp_ps::<_CMP_NEQ_UQ>(c, _mm256_setzero_ps());
                _mm256_blendv_ps(y, x, m)
            }
        }
        #[inline(always)]
        unsafe fn exp2i(k: __m256) -> __m256 {
            unsafe {
                let ki = _mm256_cvtps_epi32(k);
                let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(ki, _mm256_set1_epi32(127)));
                _mm256_castsi256_ps(bits)
            }
        }
        #[inline(always)]
        unsafe fn to_array(v: __m256) -> [f32; LANES] {
            let mut out = [0.0f32; LANES];
            unsafe { _mm256_storeu_ps(out.as_mut_ptr(), v) };
            out
        }
    }
}

// ---------------------------------------------------------------------------
// NEON backend (aarch64): an 8-lane block is a pair of float32x4_t.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{Simd8, LANES};
    use std::arch::aarch64::*;

    #[derive(Clone, Copy)]
    pub(super) struct Neon;

    type F2 = (float32x4_t, float32x4_t);

    impl Simd8 for Neon {
        type F = F2;
        #[inline(always)]
        unsafe fn load(p: *const f32) -> F2 {
            unsafe { (vld1q_f32(p), vld1q_f32(p.add(4))) }
        }
        #[inline(always)]
        unsafe fn store(p: *mut f32, v: F2) {
            unsafe {
                vst1q_f32(p, v.0);
                vst1q_f32(p.add(4), v.1);
            }
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> F2 {
            unsafe { (vdupq_n_f32(x), vdupq_n_f32(x)) }
        }
        #[inline(always)]
        unsafe fn add(a: F2, b: F2) -> F2 {
            unsafe { (vaddq_f32(a.0, b.0), vaddq_f32(a.1, b.1)) }
        }
        #[inline(always)]
        unsafe fn sub(a: F2, b: F2) -> F2 {
            unsafe { (vsubq_f32(a.0, b.0), vsubq_f32(a.1, b.1)) }
        }
        #[inline(always)]
        unsafe fn mul(a: F2, b: F2) -> F2 {
            unsafe { (vmulq_f32(a.0, b.0), vmulq_f32(a.1, b.1)) }
        }
        #[inline(always)]
        unsafe fn div(a: F2, b: F2) -> F2 {
            unsafe { (vdivq_f32(a.0, b.0), vdivq_f32(a.1, b.1)) }
        }
        #[inline(always)]
        unsafe fn max(a: F2, b: F2) -> F2 {
            // vmaxq would propagate NaN from either side; compare+select
            // reproduces `if a > b { a } else { b }` (NaN ⇒ b) instead.
            unsafe {
                let m0 = vcgtq_f32(a.0, b.0);
                let m1 = vcgtq_f32(a.1, b.1);
                (vbslq_f32(m0, a.0, b.0), vbslq_f32(m1, a.1, b.1))
            }
        }
        #[inline(always)]
        unsafe fn min(a: F2, b: F2) -> F2 {
            unsafe {
                let m0 = vcltq_f32(a.0, b.0);
                let m1 = vcltq_f32(a.1, b.1);
                (vbslq_f32(m0, a.0, b.0), vbslq_f32(m1, a.1, b.1))
            }
        }
        #[inline(always)]
        unsafe fn mul_add(a: F2, b: F2, c: F2) -> F2 {
            // vfmaq_f32(acc, x, y) = acc + x*y
            unsafe { (vfmaq_f32(c.0, a.0, b.0), vfmaq_f32(c.1, a.1, b.1)) }
        }
        #[inline(always)]
        unsafe fn floor(a: F2) -> F2 {
            unsafe { (vrndmq_f32(a.0), vrndmq_f32(a.1)) }
        }
        #[inline(always)]
        unsafe fn sqrt(a: F2) -> F2 {
            unsafe { (vsqrtq_f32(a.0), vsqrtq_f32(a.1)) }
        }
        #[inline(always)]
        unsafe fn abs(a: F2) -> F2 {
            unsafe { (vabsq_f32(a.0), vabsq_f32(a.1)) }
        }
        #[inline(always)]
        unsafe fn neg(a: F2) -> F2 {
            unsafe { (vnegq_f32(a.0), vnegq_f32(a.1)) }
        }
        #[inline(always)]
        unsafe fn select_gt(a: F2, b: F2, x: F2, y: F2) -> F2 {
            unsafe {
                let m0 = vcgtq_f32(a.0, b.0);
                let m1 = vcgtq_f32(a.1, b.1);
                (vbslq_f32(m0, x.0, y.0), vbslq_f32(m1, x.1, y.1))
            }
        }
        #[inline(always)]
        unsafe fn select_neq0(c: F2, x: F2, y: F2) -> F2 {
            unsafe {
                let z = vdupq_n_f32(0.0);
                // eq-mask picks the else-branch; NaN compares not-equal.
                let e0 = vceqq_f32(c.0, z);
                let e1 = vceqq_f32(c.1, z);
                (vbslq_f32(e0, y.0, x.0), vbslq_f32(e1, y.1, x.1))
            }
        }
        #[inline(always)]
        unsafe fn exp2i(k: F2) -> F2 {
            unsafe {
                let b127 = vdupq_n_s32(127);
                let k0 = vshlq_n_s32::<23>(vaddq_s32(vcvtq_s32_f32(k.0), b127));
                let k1 = vshlq_n_s32::<23>(vaddq_s32(vcvtq_s32_f32(k.1), b127));
                (vreinterpretq_f32_s32(k0), vreinterpretq_f32_s32(k1))
            }
        }
        #[inline(always)]
        unsafe fn to_array(v: F2) -> [f32; LANES] {
            let mut out = [0.0f32; LANES];
            unsafe {
                vst1q_f32(out.as_mut_ptr(), v.0);
                vst1q_f32(out.as_mut_ptr().add(4), v.1);
            }
            out
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch: one `#[target_feature]` entry per kernel per backend
// ---------------------------------------------------------------------------

/// Generates, for each listed kernel: an AVX2 entry (monomorphized inside
/// `#[target_feature(enable = "avx2,fma")]` so the generic body compiles
/// to real vector code), a NEON entry, and the runtime dispatcher.
macro_rules! dispatch_kernels {
    ($(fn $name:ident($($arg:ident: $ty:ty),*) $(-> $ret:ty)? = $impl_fn:ident;)*) => {
        #[cfg(target_arch = "x86_64")]
        mod avx2_entry {
            use super::*;
            $(
                #[target_feature(enable = "avx2,fma")]
                pub(super) unsafe fn $name($($arg: $ty),*) $(-> $ret)? {
                    unsafe { $impl_fn::<avx2::Avx2>($($arg),*) }
                }
            )*
        }
        #[cfg(target_arch = "aarch64")]
        mod neon_entry {
            use super::*;
            $(
                #[target_feature(enable = "neon")]
                pub(super) unsafe fn $name($($arg: $ty),*) $(-> $ret)? {
                    unsafe { $impl_fn::<neon::Neon>($($arg),*) }
                }
            )*
        }
        $(
            #[inline]
            pub(crate) unsafe fn $name($($arg: $ty),*) $(-> $ret)? {
                match path() {
                    #[cfg(target_arch = "x86_64")]
                    SimdPath::Avx2 => unsafe { avx2_entry::$name($($arg),*) },
                    #[cfg(target_arch = "aarch64")]
                    SimdPath::Neon => unsafe { neon_entry::$name($($arg),*) },
                    _ => unsafe { $impl_fn::<scalar8::Scalar8>($($arg),*) },
                }
            }
        )*
    };
}

dispatch_kernels! {
    fn un_to(op: UnOp, src: &[f32], dst: *mut f32) = un_to_impl;
    fn bin_to(op: BinOp, a: &[f32], b: &[f32], dst: *mut f32) = bin_to_impl;
    fn select_to(c: &[f32], a: &[f32], b: &[f32], dst: *mut f32) = select_to_impl;
    fn exp_scaled_sub_to(src: &[f32], scale: f32, m: f32, dst: *mut f32) = exp_scaled_sub_to_impl;
    fn sgemm_micro_4x16(kc: usize, ap: &[f32], bp: &[f32], ldb: usize, c: *mut f32, ldc: usize) = sgemm_micro_4x16_impl;
    fn un_ip_d(op: UnOp, dst: &mut [f32]) = un_ip_impl;
    fn bin_ip_d(op: BinOp, dst: &mut [f32], rhs: &[f32]) = bin_ip_impl;
    fn select_ip_d(dst: &mut [f32], a: &[f32], b: &[f32]) = select_ip_impl;
    fn sum_d(xs: &[f32]) -> f32 = sum_impl;
    fn dot_d(a: &[f32], b: &[f32]) -> f32 = dot_impl;
    fn max_scaled_d(xs: &[f32], scale: f32) -> f32 = max_scaled_impl;
    fn min_d(xs: &[f32]) -> f32 = min_impl;
    fn sum_exp_sub_d(xs: &[f32], m: f32) -> f32 = sum_exp_sub_impl;
    fn axpy_d(s: f32, x: &[f32], dst: &mut [f32]) = axpy_impl;
}

// Safe wrappers for the slice-only kernels (the `*_to` raw-pointer entries
// above stay unsafe: callers hand them possibly-uninitialized bands).

/// In-place unary block kernel: `dst[i] = op(dst[i])`.
#[inline]
pub(crate) fn un_ip(op: UnOp, dst: &mut [f32]) {
    unsafe { un_ip_d(op, dst) }
}

/// In-place binary block kernel: `dst[i] = op(dst[i], rhs[i])`.
#[inline]
pub(crate) fn bin_ip(op: BinOp, dst: &mut [f32], rhs: &[f32]) {
    unsafe { bin_ip_d(op, dst, rhs) }
}

/// In-place select: `dst[i] = if dst[i] != 0.0 { a[i] } else { b[i] }`.
#[inline]
pub(crate) fn select_ip(dst: &mut [f32], a: &[f32], b: &[f32]) {
    unsafe { select_ip_d(dst, a, b) }
}

/// Sum — bit-identical to the seed `kernels::sum` fold on every path.
#[inline]
pub(crate) fn sum(xs: &[f32]) -> f32 {
    unsafe { sum_d(xs) }
}

/// Dot — bit-identical to the seed `kernels::dot` fold on every path.
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    unsafe { dot_d(a, b) }
}

/// Max of `xs[i] * scale` (fixed 8-lane fold; the scaled-softmax prologue).
#[inline]
pub(crate) fn max_scaled(xs: &[f32], scale: f32) -> f32 {
    unsafe { max_scaled_d(xs, scale) }
}

/// Max element. Routed through [`max_scaled`] with `scale = 1.0` (bit-exact
/// multiply) so the plain and scaled row-max folds stay bitwise-equal.
#[inline]
pub(crate) fn max(xs: &[f32]) -> f32 {
    unsafe { max_scaled_d(xs, 1.0) }
}

/// Min element (same fixed fold shape as [`max`]).
#[inline]
pub(crate) fn min(xs: &[f32]) -> f32 {
    unsafe { min_d(xs) }
}

/// `Σ fast_exp(xs[i] − m)` — the logsumexp inner sum.
#[inline]
pub(crate) fn sum_exp_sub(xs: &[f32], m: f32) -> f32 {
    unsafe { sum_exp_sub_d(xs, m) }
}

/// `dst[i] += s * x[i]` — bit-identical to the seed `kernels::axpy`.
#[inline]
pub(crate) fn axpy(s: f32, x: &[f32], dst: &mut [f32]) {
    unsafe { axpy_d(s, x, dst) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Serializes tests that toggle the global path.
    static TLOCK: Mutex<()> = Mutex::new(());

    fn data(n: usize) -> Vec<f32> {
        // Deterministic mix of signs, magnitudes, zeros and exact values.
        (0..n)
            .map(|i| {
                let x = (i as f32) * 0.731 - (n as f32) * 0.3;
                if i % 11 == 0 {
                    0.0
                } else {
                    x
                }
            })
            .collect()
    }

    fn all_unops() -> Vec<UnOp> {
        vec![
            UnOp::Neg,
            UnOp::Relu,
            UnOp::Exp,
            UnOp::Square,
            UnOp::Abs,
            UnOp::Sigmoid,
            UnOp::Tanh,
            UnOp::Gelu,
            UnOp::AddScalar(0.37),
            UnOp::MulScalar(-1.25),
            UnOp::Clamp(-2.0, 3.0),
            UnOp::LeakyRelu(0.01),
        ]
    }

    #[test]
    fn active_path_matches_forced_scalar_bitwise() {
        let _g = TLOCK.lock().unwrap();
        let was = path();
        let n = 37; // exercises both the block loop and the tail
        let src = data(n);
        for op in all_unops() {
            set_simd_enabled(true);
            let mut on = vec![0.0f32; n];
            unsafe { un_to(op, &src, on.as_mut_ptr()) };
            set_simd_enabled(false);
            let mut off = vec![0.0f32; n];
            unsafe { un_to(op, &src, off.as_mut_ptr()) };
            for i in 0..n {
                assert_eq!(on[i].to_bits(), off[i].to_bits(), "{op:?} i={i}");
            }
        }
        // sqrt separately on non-negative inputs (NaN payloads of
        // sqrt(negative) are hardware-defined and may differ).
        let pos: Vec<f32> = src.iter().map(|v| v.abs()).collect();
        set_simd_enabled(true);
        let mut on = vec![0.0f32; n];
        unsafe { un_to(UnOp::Sqrt, &pos, on.as_mut_ptr()) };
        set_simd_enabled(false);
        let mut off = vec![0.0f32; n];
        unsafe { un_to(UnOp::Sqrt, &pos, off.as_mut_ptr()) };
        assert_eq!(on, off);

        let b: Vec<f32> = data(n).iter().rev().cloned().collect();
        for op in [BinOp::Add, BinOp::Sub, BinOp::Mul, BinOp::Div, BinOp::Max, BinOp::Min] {
            set_simd_enabled(true);
            let mut on = vec![0.0f32; n];
            unsafe { bin_to(op, &src, &b, on.as_mut_ptr()) };
            set_simd_enabled(false);
            let mut off = vec![0.0f32; n];
            unsafe { bin_to(op, &src, &b, off.as_mut_ptr()) };
            for i in 0..n {
                assert_eq!(on[i].to_bits(), off[i].to_bits(), "{op:?} i={i}");
            }
        }
        for on_now in [true, false] {
            set_simd_enabled(on_now);
            let s1 = sum(&src);
            let d1 = dot(&src, &b);
            let m1 = max(&src);
            let mn1 = min(&src);
            let se1 = sum_exp_sub(&src, m1);
            set_simd_enabled(!on_now);
            assert_eq!(s1.to_bits(), sum(&src).to_bits());
            assert_eq!(d1.to_bits(), dot(&src, &b).to_bits());
            assert_eq!(m1.to_bits(), max(&src).to_bits());
            assert_eq!(mn1.to_bits(), min(&src).to_bits());
            assert_eq!(se1.to_bits(), sum_exp_sub(&src, m1).to_bits());
        }
        set_simd_enabled(was.is_vector());
    }

    #[test]
    fn exp_kernel_is_fast_exp_lane_for_lane() {
        let _g = TLOCK.lock().unwrap();
        let src = data(41);
        let mut out = vec![0.0f32; src.len()];
        unsafe { un_to(UnOp::Exp, &src, out.as_mut_ptr()) };
        for (i, &v) in src.iter().enumerate() {
            assert_eq!(
                out[i].to_bits(),
                crate::ops::kernels::fast_exp(v).to_bits(),
                "i={i} v={v}"
            );
        }
    }

    #[test]
    fn sum_and_max_match_seed_scalar_folds() {
        let _g = TLOCK.lock().unwrap();
        let xs = data(100);
        // The seed `kernels::sum` fold, written out longhand.
        let mut acc = [0.0f32; 8];
        let chunks = xs.chunks_exact(8);
        let rem = chunks.remainder();
        for c in chunks {
            for i in 0..8 {
                acc[i] += c[i];
            }
        }
        let mut tail = 0.0;
        for &v in rem {
            tail += v;
        }
        let want = acc.iter().sum::<f32>() + tail;
        assert_eq!(sum(&xs).to_bits(), want.to_bits());
        // max_scaled(·, 1.0) must equal max of the pre-scaled values.
        let scaled: Vec<f32> = xs.iter().map(|&v| v * 0.37).collect();
        assert_eq!(
            max_scaled(&xs, 0.37).to_bits(),
            max(&scaled).to_bits()
        );
    }

    #[test]
    fn tanh_kernel_accuracy() {
        let mut x = -6.0f32;
        while x < 6.0 {
            let want = (x as f64).tanh();
            let got = tanh_s(x) as f64;
            assert!(
                (got - want).abs() < 1e-6,
                "x={x} got={got} want={want}"
            );
            x += 0.0173;
        }
        assert_eq!(tanh_s(0.0), 0.0);
        assert_eq!(tanh_s(20.0), 1.0);
        assert_eq!(tanh_s(-20.0), -1.0);
    }

    #[test]
    fn sgemm_micro_tile_matches_mul_add_reference() {
        let _g = TLOCK.lock().unwrap();
        let kc = 7;
        let ap: Vec<f32> = (0..kc * 4).map(|i| (i as f32) * 0.31 - 2.0).collect();
        let bp: Vec<f32> = (0..kc * 16).map(|i| (i as f32) * 0.17 - 5.0).collect();
        let ldc = 20;
        let mut c = vec![1.0f32; 4 * ldc];
        unsafe { sgemm_micro_4x16(kc, &ap, &bp, 16, c.as_mut_ptr(), ldc) };
        for i in 0..4 {
            for j in 0..16 {
                let mut acc = 0.0f32;
                for p in 0..kc {
                    acc = ap[p * 4 + i].mul_add(bp[p * 16 + j], acc);
                }
                let want = 1.0 + acc;
                assert_eq!(
                    c[i * ldc + j].to_bits(),
                    want.to_bits(),
                    "i={i} j={j}"
                );
            }
            // columns beyond the tile untouched
            for j in 16..ldc {
                assert_eq!(c[i * ldc + j], 1.0);
            }
        }
    }

    #[test]
    fn select_kernels_match_scalar_select() {
        let _g = TLOCK.lock().unwrap();
        let n = 19;
        let c: Vec<f32> = (0..n).map(|i| (i % 3) as f32 - 1.0).collect();
        let a = data(n);
        let b: Vec<f32> = data(n).iter().map(|v| v + 1.0).collect();
        let mut out = vec![0.0f32; n];
        unsafe { select_to(&c, &a, &b, out.as_mut_ptr()) };
        for i in 0..n {
            let want = crate::ops::kernels::select(c[i], a[i], b[i]);
            assert_eq!(out[i].to_bits(), want.to_bits(), "i={i}");
        }
        let mut ip = c.clone();
        select_ip(&mut ip, &a, &b);
        assert_eq!(ip, out);
    }

    #[test]
    fn toggle_and_report_names() {
        let _g = TLOCK.lock().unwrap();
        let was = path();
        set_simd_enabled(false);
        assert_eq!(path(), SimdPath::Scalar);
        assert!(!path().is_vector());
        assert_eq!(path().name(), "scalar");
        set_simd_enabled(true);
        #[cfg(target_arch = "x86_64")]
        assert!(matches!(path(), SimdPath::Avx2 | SimdPath::Scalar));
        #[cfg(target_arch = "aarch64")]
        assert_eq!(path(), SimdPath::Neon);
        set_simd_enabled(was.is_vector());
    }
}
