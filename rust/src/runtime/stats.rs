//! Engine-level execution statistics.
//!
//! Thread-local counters fed by the execution layer (`ops::exec`) and the
//! lazy expression-graph subsystem (`crate::graph`), surfaced in the CLI's
//! engine report and asserted by the fusion tests ("a fused 3-op chain is
//! exactly one dispatch and one output allocation").
//!
//! **Scope:** every bulk-kernel entry point is instrumented — the
//! elementwise / unary / row-map / reduction / fused funnels in
//! `ops::exec`, plus matmul (`matmul`, `matmul_nt`), conv2d forward and
//! both backward passes, pooling, and the fused cross-entropy forward.
//! Attention is a composition of instrumented kernels (two matmuls and a
//! softmax), so its launches are counted through its constituents. On a
//! conv/MLP training step the report therefore reflects *every* kernel
//! launch, not just the fusable families.
//!
//! The program cache of the lazy graph subsystem reports here too:
//! `program_cache_hits` / `program_cache_misses` count compiled-plan
//! reuse (a miss is exactly one region-partitioning + tape-construction
//! pass), and `fusion_bailouts` counts regions the partitioner degraded
//! to per-op dispatch because they exceeded the fused-input or
//! stack-depth caps.
//!
//! The counters are **thread-local** on purpose: dispatches happen on the
//! thread that calls into the execution layer (pool workers never dispatch
//! — nested parallelism degrades to serial), so a test or a bench reads an
//! exact count for the work *it* issued, immune to whatever the other test
//! threads are doing. The report therefore describes the calling thread's
//! view, which for the single-threaded CLI path is the whole process.

use std::cell::Cell;

thread_local! {
    static EXEC_DISPATCHES: Cell<u64> = const { Cell::new(0) };
    static OUTPUT_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static FUSED_KERNELS: Cell<u64> = const { Cell::new(0) };
    static FUSED_OPS: Cell<u64> = const { Cell::new(0) };
    static FUSED_ELEMS: Cell<u64> = const { Cell::new(0) };
    static PROGRAM_CACHE_HITS: Cell<u64> = const { Cell::new(0) };
    static PROGRAM_CACHE_MISSES: Cell<u64> = const { Cell::new(0) };
    static FUSION_BAILOUTS: Cell<u64> = const { Cell::new(0) };
    static SIMD_BLOCKS: Cell<u64> = const { Cell::new(0) };
}

/// Point-in-time snapshot of this thread's execution counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecStats {
    /// Kernel dispatches through the exec-layer funnels (`binary_op`,
    /// `unary_op`, `map_rows`, the reduction drivers, `fused_op`,
    /// `fused_reduce`). One eager op = one dispatch; one fused region =
    /// one dispatch regardless of how many ops it contains.
    pub exec_dispatches: u64,
    /// Output buffers taken from the tensor pool (or freshly allocated)
    /// by those funnels. A fused region takes exactly one.
    pub output_allocs: u64,
    /// Fused-region kernels launched by the lazy graph subsystem.
    pub fused_kernels: u64,
    /// Total graph ops folded into those kernels (the intermediates a
    /// fused kernel avoided materializing is `fused_ops - fused_kernels`).
    pub fused_ops: u64,
    /// Output elements produced by fused kernels.
    pub fused_elems: u64,
    /// Lazy-graph `eval()` calls that reused a cached compiled program
    /// (skipping region partitioning and tape construction entirely).
    pub program_cache_hits: u64,
    /// Lazy-graph `eval()` calls that compiled a fresh program (exactly
    /// one region-partitioning + tape-construction pass each).
    pub program_cache_misses: u64,
    /// Regions degraded to per-op dispatch because they exceeded the
    /// fused-input or stack-depth caps, counted per eval: a cached plan
    /// containing degraded regions re-counts them on every execution.
    pub fusion_bailouts: u64,
    /// Full 8-lane vector blocks processed by the SIMD-funneled kernels
    /// (`ops::exec::binary_simd` / `unary_simd` / row kernels), counted
    /// at dispatch on the calling thread. Zero when the scalar path is
    /// active (`MINITENSOR_SIMD=off` or no AVX2/NEON) — the quickest way
    /// to confirm which path a bench actually ran.
    pub simd_blocks: u64,
}

impl ExecStats {
    /// Counter increments since an earlier snapshot on the same thread.
    pub fn delta(&self, since: &ExecStats) -> ExecStats {
        ExecStats {
            exec_dispatches: self.exec_dispatches - since.exec_dispatches,
            output_allocs: self.output_allocs - since.output_allocs,
            fused_kernels: self.fused_kernels - since.fused_kernels,
            fused_ops: self.fused_ops - since.fused_ops,
            fused_elems: self.fused_elems - since.fused_elems,
            program_cache_hits: self.program_cache_hits - since.program_cache_hits,
            program_cache_misses: self.program_cache_misses - since.program_cache_misses,
            fusion_bailouts: self.fusion_bailouts - since.fusion_bailouts,
            simd_blocks: self.simd_blocks - since.simd_blocks,
        }
    }
}

/// Snapshot this thread's counters.
pub fn snapshot() -> ExecStats {
    ExecStats {
        exec_dispatches: EXEC_DISPATCHES.with(Cell::get),
        output_allocs: OUTPUT_ALLOCS.with(Cell::get),
        fused_kernels: FUSED_KERNELS.with(Cell::get),
        fused_ops: FUSED_OPS.with(Cell::get),
        fused_elems: FUSED_ELEMS.with(Cell::get),
        program_cache_hits: PROGRAM_CACHE_HITS.with(Cell::get),
        program_cache_misses: PROGRAM_CACHE_MISSES.with(Cell::get),
        fusion_bailouts: FUSION_BAILOUTS.with(Cell::get),
        simd_blocks: SIMD_BLOCKS.with(Cell::get),
    }
}

/// Snapshot this thread's counters and reset them to zero.
///
/// The interval-rate primitive for long-running processes: a serve
/// worker (or any periodic reporter) calls `take()` once per reporting
/// interval and gets the increments since the previous call, instead of
/// process-lifetime monotonic totals. Only the calling thread's
/// counters are affected.
pub fn take() -> ExecStats {
    let s = snapshot();
    EXEC_DISPATCHES.with(|c| c.set(0));
    OUTPUT_ALLOCS.with(|c| c.set(0));
    FUSED_KERNELS.with(|c| c.set(0));
    FUSED_OPS.with(|c| c.set(0));
    FUSED_ELEMS.with(|c| c.set(0));
    PROGRAM_CACHE_HITS.with(|c| c.set(0));
    PROGRAM_CACHE_MISSES.with(|c| c.set(0));
    FUSION_BAILOUTS.with(|c| c.set(0));
    SIMD_BLOCKS.with(|c| c.set(0));
    s
}

/// One exec-layer kernel dispatch (called by the funnels in `ops::exec`).
pub(crate) fn record_dispatch() {
    EXEC_DISPATCHES.with(|c| c.set(c.get() + 1));
}

/// One output buffer drawn for an exec-layer kernel.
pub(crate) fn record_output_alloc() {
    OUTPUT_ALLOCS.with(|c| c.set(c.get() + 1));
}

/// One fused-region kernel covering `ops` graph ops and `elems` output
/// elements (called by the graph evaluator through `ops::exec`).
pub(crate) fn record_fused(ops: usize, elems: usize) {
    FUSED_KERNELS.with(|c| c.set(c.get() + 1));
    FUSED_OPS.with(|c| c.set(c.get() + ops as u64));
    FUSED_ELEMS.with(|c| c.set(c.get() + elems as u64));
}

/// One lazy-graph `eval()` that reused a cached compiled program.
pub(crate) fn record_program_cache_hit() {
    PROGRAM_CACHE_HITS.with(|c| c.set(c.get() + 1));
}

/// One lazy-graph `eval()` that compiled (and cached) a fresh program.
pub(crate) fn record_program_cache_miss() {
    PROGRAM_CACHE_MISSES.with(|c| c.set(c.get() + 1));
}

/// One region degraded to per-op dispatch by a partitioner resource cap.
pub(crate) fn record_fusion_bailout() {
    FUSION_BAILOUTS.with(|c| c.set(c.get() + 1));
}

/// Re-record `n` degraded regions at once — used when a cached plan that
/// contains degraded regions is re-executed, so `fusion_bailouts` keeps
/// per-eval semantics (degraded regions *dispatched*, not merely
/// compiled) whether the plan came from the cache or a fresh compile.
pub(crate) fn record_fusion_bailouts(n: u64) {
    FUSION_BAILOUTS.with(|c| c.set(c.get() + n));
}

/// Vector blocks processed by a SIMD-funneled dispatch (`n / LANES` full
/// 8-lane blocks; the scalar tail is not counted). Recorded on the
/// dispatching thread, and only when a vector path is active.
pub(crate) fn record_simd_blocks(blocks: u64) {
    SIMD_BLOCKS.with(|c| c.set(c.get() + blocks));
}

/// Render the engine report block: worker-thread count, detected SIMD
/// path, dispatch counters, and graph-fusion totals for this thread.
pub fn report() -> String {
    let s = snapshot();
    let saved = s.fused_ops.saturating_sub(s.fused_kernels);
    format!(
        "engine: threads={} simd={} lanes={} dispatches={} output_allocs={} simd_blocks={}\n\
         graph:  fused_kernels={} fused_ops={} intermediates_avoided={} fused_elems={}\n\
         cache:  program_hits={} program_misses={} fusion_bailouts={}\n",
        super::parallel::num_threads(),
        super::simd::path().name(),
        super::simd::LANES,
        s.exec_dispatches,
        s.output_allocs,
        s.simd_blocks,
        s.fused_kernels,
        s.fused_ops,
        saved,
        s.fused_elems,
        s.program_cache_hits,
        s.program_cache_misses,
        s.fusion_bailouts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotonic_and_delta_subtracts() {
        let a = snapshot();
        record_dispatch();
        record_output_alloc();
        record_fused(3, 100);
        record_program_cache_hit();
        record_program_cache_miss();
        record_fusion_bailout();
        record_simd_blocks(4);
        let b = snapshot();
        let d = b.delta(&a);
        assert_eq!(d.exec_dispatches, 1);
        assert_eq!(d.output_allocs, 1);
        assert_eq!(d.fused_kernels, 1);
        assert_eq!(d.fused_ops, 3);
        assert_eq!(d.fused_elems, 100);
        assert_eq!(d.program_cache_hits, 1);
        assert_eq!(d.program_cache_misses, 1);
        assert_eq!(d.fusion_bailouts, 1);
        assert_eq!(d.simd_blocks, 4);
    }

    #[test]
    fn report_mentions_threads_and_fusion() {
        let r = report();
        assert!(r.contains("threads="));
        assert!(r.contains("simd="));
        assert!(r.contains("lanes=8"));
        assert!(r.contains("fused_kernels="));
        assert!(r.contains("program_hits="));
        assert!(r.contains("fusion_bailouts="));
    }

    #[test]
    fn take_resets_only_the_calling_thread() {
        // Run on a fresh thread so concurrent unit tests on this thread's
        // counters can't interleave between the take() calls.
        std::thread::spawn(|| {
            record_dispatch();
            record_fused(2, 8);
            record_simd_blocks(3);
            let taken = take();
            assert_eq!(taken.exec_dispatches, 1);
            assert_eq!(taken.fused_kernels, 1);
            assert_eq!(taken.fused_ops, 2);
            assert_eq!(taken.fused_elems, 8);
            assert_eq!(taken.simd_blocks, 3);
            // After take(), the interval restarts from zero.
            assert_eq!(take(), ExecStats::default());
            record_dispatch();
            assert_eq!(take().exec_dispatches, 1);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn counters_are_thread_local() {
        let before = snapshot();
        std::thread::spawn(|| {
            record_dispatch();
            record_fused(5, 10);
        })
        .join()
        .unwrap();
        // The other thread's increments must not leak into this thread.
        assert_eq!(snapshot(), before);
    }
}
