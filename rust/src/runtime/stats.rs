//! Engine-level execution statistics.
//!
//! Per-thread counters fed by the execution layer (`ops::exec`) and the
//! lazy expression-graph subsystem (`crate::graph`), surfaced in the CLI's
//! engine report and asserted by the fusion tests ("a fused 3-op chain is
//! exactly one dispatch and one output allocation").
//!
//! **Scope:** every bulk-kernel entry point is instrumented — the
//! elementwise / unary / row-map / reduction / fused funnels in
//! `ops::exec`, plus matmul (`matmul`, `matmul_nt`), conv2d forward and
//! both backward passes, pooling, and the fused cross-entropy forward.
//! Attention is a composition of instrumented kernels (two matmuls and a
//! softmax), so its launches are counted through its constituents. On a
//! conv/MLP training step the report therefore reflects *every* kernel
//! launch, not just the fusable families.
//!
//! The program cache of the lazy graph subsystem reports here too:
//! `program_cache_hits` / `program_cache_misses` count compiled-plan
//! reuse (a miss is exactly one region-partitioning + tape-construction
//! pass), and `fusion_bailouts` counts regions the partitioner degraded
//! to per-op dispatch because they exceeded the fused-input or
//! stack-depth caps.
//!
//! **Storage** (since PR 9): the record funnels write the process-wide
//! sharded registry in [`metrics`](super::metrics) — one slot array per
//! thread — and this module derives its view as *this thread's shard
//! minus a thread-local baseline*. That keeps the contract the tests
//! rely on: dispatches happen on the thread that calls into the
//! execution layer (pool workers never dispatch — nested parallelism
//! degrades to serial), so a test or a bench reads an exact count for
//! the work *it* issued, immune to the other test threads. Meanwhile the
//! registry's cross-thread merge stays monotone: [`take`] only advances
//! this thread's baseline, it never rolls the shard (or the scraped
//! `minitensor_exec_*` totals) backward. The one coupling:
//! `MINITENSOR_METRICS=off` freezes these counters too.

use std::cell::Cell;

use super::metrics::{self, Id};

/// The nine [`Id`]s backing [`ExecStats`], in field order.
const STAT_IDS: [Id; 9] = [
    Id::ExecDispatches,
    Id::OutputAllocs,
    Id::FusedKernels,
    Id::FusedOps,
    Id::FusedElems,
    Id::ProgramCacheHits,
    Id::ProgramCacheMisses,
    Id::FusionBailouts,
    Id::SimdBlocks,
];

thread_local! {
    /// Shard values at the last [`take`] on this thread — the zero point
    /// of this thread's interval view.
    static BASELINE: Cell<[u64; 9]> = const { Cell::new([0; 9]) };
}

/// This thread's raw shard values for the nine stat slots.
fn thread_raw() -> [u64; 9] {
    let mut out = [0u64; 9];
    for (o, &id) in out.iter_mut().zip(STAT_IDS.iter()) {
        *o = metrics::thread_get(id);
    }
    out
}

/// Point-in-time snapshot of this thread's execution counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecStats {
    /// Kernel dispatches through the exec-layer funnels (`binary_op`,
    /// `unary_op`, `map_rows`, the reduction drivers, `fused_op`,
    /// `fused_reduce`). One eager op = one dispatch; one fused region =
    /// one dispatch regardless of how many ops it contains.
    pub exec_dispatches: u64,
    /// Output buffers taken from the tensor pool (or freshly allocated)
    /// by those funnels. A fused region takes exactly one.
    pub output_allocs: u64,
    /// Fused-region kernels launched by the lazy graph subsystem.
    pub fused_kernels: u64,
    /// Total graph ops folded into those kernels (the intermediates a
    /// fused kernel avoided materializing is `fused_ops - fused_kernels`).
    pub fused_ops: u64,
    /// Output elements produced by fused kernels.
    pub fused_elems: u64,
    /// Lazy-graph `eval()` calls that reused a cached compiled program
    /// (skipping region partitioning and tape construction entirely).
    pub program_cache_hits: u64,
    /// Lazy-graph `eval()` calls that compiled a fresh program (exactly
    /// one region-partitioning + tape-construction pass each).
    pub program_cache_misses: u64,
    /// Regions degraded to per-op dispatch because they exceeded the
    /// fused-input or stack-depth caps, counted per eval: a cached plan
    /// containing degraded regions re-counts them on every execution.
    pub fusion_bailouts: u64,
    /// Full 8-lane vector blocks processed by the SIMD-funneled kernels
    /// (`ops::exec::binary_simd` / `unary_simd` / row kernels), counted
    /// at dispatch on the calling thread. Zero when the scalar path is
    /// active (`MINITENSOR_SIMD=off` or no AVX2/NEON) — the quickest way
    /// to confirm which path a bench actually ran.
    pub simd_blocks: u64,
}

impl ExecStats {
    fn from_raw(raw: [u64; 9]) -> ExecStats {
        ExecStats {
            exec_dispatches: raw[0],
            output_allocs: raw[1],
            fused_kernels: raw[2],
            fused_ops: raw[3],
            fused_elems: raw[4],
            program_cache_hits: raw[5],
            program_cache_misses: raw[6],
            fusion_bailouts: raw[7],
            simd_blocks: raw[8],
        }
    }

    /// Counter increments since an earlier snapshot on the same thread.
    pub fn delta(&self, since: &ExecStats) -> ExecStats {
        ExecStats {
            exec_dispatches: self.exec_dispatches - since.exec_dispatches,
            output_allocs: self.output_allocs - since.output_allocs,
            fused_kernels: self.fused_kernels - since.fused_kernels,
            fused_ops: self.fused_ops - since.fused_ops,
            fused_elems: self.fused_elems - since.fused_elems,
            program_cache_hits: self.program_cache_hits - since.program_cache_hits,
            program_cache_misses: self.program_cache_misses - since.program_cache_misses,
            fusion_bailouts: self.fusion_bailouts - since.fusion_bailouts,
            simd_blocks: self.simd_blocks - since.simd_blocks,
        }
    }
}

/// Snapshot this thread's counters.
pub fn snapshot() -> ExecStats {
    let raw = thread_raw();
    let base = BASELINE.with(Cell::get);
    let mut rel = [0u64; 9];
    for i in 0..9 {
        rel[i] = raw[i] - base[i];
    }
    ExecStats::from_raw(rel)
}

/// Snapshot this thread's counters and reset them to zero.
///
/// The interval-rate primitive for long-running processes: a serve
/// worker (or any periodic reporter) calls `take()` once per reporting
/// interval and gets the increments since the previous call, instead of
/// process-lifetime monotonic totals. Only the calling thread's view is
/// affected — the reset advances a thread-local baseline, so the
/// process-wide `minitensor_exec_*` counters in
/// [`metrics`](super::metrics) stay monotone.
pub fn take() -> ExecStats {
    let raw = thread_raw();
    let base = BASELINE.with(Cell::get);
    let mut rel = [0u64; 9];
    for i in 0..9 {
        rel[i] = raw[i] - base[i];
    }
    BASELINE.with(|b| b.set(raw));
    ExecStats::from_raw(rel)
}

/// One exec-layer kernel dispatch (called by the funnels in `ops::exec`).
pub(crate) fn record_dispatch() {
    metrics::add(Id::ExecDispatches, 1);
}

/// One output buffer drawn for an exec-layer kernel.
pub(crate) fn record_output_alloc() {
    metrics::add(Id::OutputAllocs, 1);
}

/// One fused-region kernel covering `ops` graph ops and `elems` output
/// elements (called by the graph evaluator through `ops::exec`).
pub(crate) fn record_fused(ops: usize, elems: usize) {
    metrics::add(Id::FusedKernels, 1);
    metrics::add(Id::FusedOps, ops as u64);
    metrics::add(Id::FusedElems, elems as u64);
}

/// One lazy-graph `eval()` that reused a cached compiled program.
pub(crate) fn record_program_cache_hit() {
    metrics::add(Id::ProgramCacheHits, 1);
}

/// One lazy-graph `eval()` that compiled (and cached) a fresh program.
pub(crate) fn record_program_cache_miss() {
    metrics::add(Id::ProgramCacheMisses, 1);
}

/// One region degraded to per-op dispatch by a partitioner resource cap.
pub(crate) fn record_fusion_bailout() {
    metrics::add(Id::FusionBailouts, 1);
}

/// Re-record `n` degraded regions at once — used when a cached plan that
/// contains degraded regions is re-executed, so `fusion_bailouts` keeps
/// per-eval semantics (degraded regions *dispatched*, not merely
/// compiled) whether the plan came from the cache or a fresh compile.
pub(crate) fn record_fusion_bailouts(n: u64) {
    metrics::add(Id::FusionBailouts, n);
}

/// Vector blocks processed by a SIMD-funneled dispatch (`n / LANES` full
/// 8-lane blocks; the scalar tail is not counted). Recorded on the
/// dispatching thread, and only when a vector path is active.
pub(crate) fn record_simd_blocks(blocks: u64) {
    metrics::add(Id::SimdBlocks, blocks);
}

/// Render the engine report block: worker-thread count, detected SIMD
/// path, dispatch counters, and graph-fusion totals for this thread.
pub fn report() -> String {
    let s = snapshot();
    let saved = s.fused_ops.saturating_sub(s.fused_kernels);
    format!(
        "engine: threads={} simd={} lanes={} dispatches={} output_allocs={} simd_blocks={}\n\
         graph:  fused_kernels={} fused_ops={} intermediates_avoided={} fused_elems={}\n\
         cache:  program_hits={} program_misses={} fusion_bailouts={}\n",
        super::parallel::num_threads(),
        super::simd::path().name(),
        super::simd::LANES,
        s.exec_dispatches,
        s.output_allocs,
        s.simd_blocks,
        s.fused_kernels,
        s.fused_ops,
        saved,
        s.fused_elems,
        s.program_cache_hits,
        s.program_cache_misses,
        s.fusion_bailouts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotonic_and_delta_subtracts() {
        let a = snapshot();
        record_dispatch();
        record_output_alloc();
        record_fused(3, 100);
        record_program_cache_hit();
        record_program_cache_miss();
        record_fusion_bailout();
        record_simd_blocks(4);
        let b = snapshot();
        let d = b.delta(&a);
        assert_eq!(d.exec_dispatches, 1);
        assert_eq!(d.output_allocs, 1);
        assert_eq!(d.fused_kernels, 1);
        assert_eq!(d.fused_ops, 3);
        assert_eq!(d.fused_elems, 100);
        assert_eq!(d.program_cache_hits, 1);
        assert_eq!(d.program_cache_misses, 1);
        assert_eq!(d.fusion_bailouts, 1);
        assert_eq!(d.simd_blocks, 4);
    }

    #[test]
    fn report_mentions_threads_and_fusion() {
        let r = report();
        assert!(r.contains("threads="));
        assert!(r.contains("simd="));
        assert!(r.contains("lanes=8"));
        assert!(r.contains("fused_kernels="));
        assert!(r.contains("program_hits="));
        assert!(r.contains("fusion_bailouts="));
    }

    #[test]
    fn take_resets_only_the_calling_thread() {
        // Run on a fresh thread so concurrent unit tests on this thread's
        // counters can't interleave between the take() calls.
        std::thread::spawn(|| {
            record_dispatch();
            record_fused(2, 8);
            record_simd_blocks(3);
            let taken = take();
            assert_eq!(taken.exec_dispatches, 1);
            assert_eq!(taken.fused_kernels, 1);
            assert_eq!(taken.fused_ops, 2);
            assert_eq!(taken.fused_elems, 8);
            assert_eq!(taken.simd_blocks, 3);
            // After take(), the interval restarts from zero.
            assert_eq!(take(), ExecStats::default());
            record_dispatch();
            assert_eq!(take().exec_dispatches, 1);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn counters_are_thread_local() {
        let before = snapshot();
        std::thread::spawn(|| {
            record_dispatch();
            record_fused(5, 10);
        })
        .join()
        .unwrap();
        // The other thread's increments must not leak into this thread.
        assert_eq!(snapshot(), before);
    }

    #[test]
    fn take_never_rolls_back_the_global_registry() {
        // The registry's merged counter must keep growing across a
        // take(): the reset is baseline-only.
        std::thread::spawn(|| {
            let global = |s: &crate::runtime::metrics::MetricsSnapshot| {
                s.counters
                    .iter()
                    .find(|(k, _)| k == "minitensor_exec_dispatches_total")
                    .map(|&(_, v)| v)
                    .unwrap_or(0)
            };
            let g0 = global(&crate::runtime::metrics::snapshot());
            record_dispatch();
            let _ = take();
            record_dispatch();
            let g1 = global(&crate::runtime::metrics::snapshot());
            assert!(g1 >= g0 + 2, "take() must not reset merged totals: {g0} -> {g1}");
        })
        .join()
        .unwrap();
    }
}
