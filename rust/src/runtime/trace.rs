//! Engine-wide timeline tracing: per-dispatch spans, Chrome-trace export.
//!
//! An always-compiled, off-by-default tracing subsystem. When enabled
//! (`MINITENSOR_TRACE=<path>` or [`enable`]), the instrumented layers —
//! every `ops::exec` dispatch funnel, the worker-pool chunk bodies in
//! `runtime::parallel`, the graph evaluator's compile/cache/region steps,
//! and the serve stack's per-request lifecycle — record timestamped spans
//! into fixed-capacity per-thread ring buffers (overwrite-oldest, no
//! steady-state allocation). [`chrome_trace_json`] serializes everything
//! recorded so far to Chrome trace-event JSON loadable in
//! `chrome://tracing` or <https://ui.perfetto.dev>, and [`summary`]
//! renders a top-K-by-total-time table next to the engine report.
//!
//! **Disabled cost:** the hot path is a single relaxed atomic load
//! ([`enabled`]) — no clock read, no allocation, no lock. The eager and
//! fused dispatch rates in `benches/fusion.rs` are the regression guard.
//!
//! **Recording cost:** two monotonic clock reads plus a copy into the
//! calling thread's own ring. Each ring is guarded by a mutex that is
//! uncontended except while a flush ([`events`]/[`clear`]) walks the
//! registry, so the record path never waits on other recording threads.
//!
//! **Capacity:** rings hold [`ring_capacity`] spans each (default
//! [`DEFAULT_RING_CAPACITY`], knob `MINITENSOR_TRACE_CAPACITY` or
//! [`set_ring_capacity`]); when full, the oldest span is overwritten and
//! [`dropped`] counts the loss. Capacity is read once per thread, when
//! its ring records its first span.
//!
//! Spans carry `&'static str` names/categories and up to three
//! `key=value` args (integers or static strings), so recording never
//! allocates. Tracing is observational only: it does not touch kernel
//! math, and the bitwise determinism contract (scalar ≡ SIMD ≡ any
//! thread count) holds with tracing on or off.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU32, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread ring capacity, in spans (~3 MB per active thread).
pub const DEFAULT_RING_CAPACITY: usize = 16_384;

const STATE_UNINIT: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(STATE_UNINIT);
static RING_CAP: AtomicUsize = AtomicUsize::new(0);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
static REGISTRY: Mutex<Vec<Arc<RingHandle>>> = Mutex::new(Vec::new());
static VTRACKS: Mutex<Vec<(&'static str, u32)>> = Mutex::new(Vec::new());

/// Process-wide time origin; all span timestamps are nanoseconds since
/// this instant, so spans from different threads share one timeline.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// A span argument value: an integer or a static string.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgVal {
    U(u64),
    S(&'static str),
}

/// Up to three `key=value` args per span; an empty key marks an unused slot.
pub type Args = [(&'static str, ArgVal); 3];

const NO_ARGS: Args = [("", ArgVal::U(0)); 3];

/// One recorded span, as stored in the rings and returned by [`events`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Subsystem category (`"exec"`, `"parallel"`, `"graph"`, `"serve"`).
    pub cat: &'static str,
    /// Span name within the category.
    pub name: &'static str,
    /// Start, nanoseconds since the process trace epoch.
    pub t0_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Track (Chrome `tid`) the span renders on: the recording thread's
    /// id, or a [`virtual_track`] id (e.g. the serve request track).
    pub track: u32,
    /// `key=value` tags; slots with an empty key are unused.
    pub args: Args,
}

struct RingData {
    spans: Vec<Event>,
    cap: usize,
    head: usize,
    dropped: u64,
}

struct RingHandle {
    tid: u32,
    name: String,
    data: Mutex<RingData>,
}

thread_local! {
    static RING: RefCell<Option<Arc<RingHandle>>> = const { RefCell::new(None) };
}

/// Is tracing on? One relaxed atomic load in the steady state — this is
/// the entire cost a disabled trace adds to a kernel dispatch.
#[inline]
pub fn enabled() -> bool {
    let s = STATE.load(Ordering::Relaxed);
    if s == STATE_UNINIT {
        return resolve_env();
    }
    s == STATE_ON
}

/// First-call resolution: `MINITENSOR_TRACE=<path>` turns tracing on.
#[cold]
fn resolve_env() -> bool {
    let on = env_path().is_some();
    if on {
        let _ = epoch();
    }
    let target = if on { STATE_ON } else { STATE_OFF };
    let _ = STATE.compare_exchange(STATE_UNINIT, target, Ordering::Relaxed, Ordering::Relaxed);
    STATE.load(Ordering::Relaxed) == STATE_ON
}

/// The `MINITENSOR_TRACE` output path, if set (read once per process).
pub fn env_path() -> Option<String> {
    static PATH: OnceLock<Option<String>> = OnceLock::new();
    PATH.get_or_init(|| {
        std::env::var("MINITENSOR_TRACE")
            .ok()
            .filter(|s| !s.trim().is_empty())
    })
    .clone()
}

/// Turn tracing on programmatically (equivalent to `MINITENSOR_TRACE`,
/// minus the implied output path — pair with [`write_chrome_trace`]).
pub fn enable() {
    let _ = epoch();
    STATE.store(STATE_ON, Ordering::Relaxed);
}

/// Turn tracing off. Already-recorded spans stay in the rings.
pub fn disable() {
    STATE.store(STATE_OFF, Ordering::Relaxed);
}

/// Override the per-thread ring capacity (spans). Applies to rings
/// created after the call — each thread sizes its ring at first record.
pub fn set_ring_capacity(cap: usize) {
    RING_CAP.store(cap.max(8), Ordering::Relaxed);
}

/// Per-thread ring capacity: [`set_ring_capacity`] wins, then
/// `MINITENSOR_TRACE_CAPACITY`, then [`DEFAULT_RING_CAPACITY`].
pub fn ring_capacity() -> usize {
    let v = RING_CAP.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let raw = std::env::var("MINITENSOR_TRACE_CAPACITY").ok();
    let resolved = env_ring_capacity(raw.as_deref()).unwrap_or(DEFAULT_RING_CAPACITY);
    let _ = RING_CAP.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed);
    RING_CAP.load(Ordering::Relaxed)
}

/// Parse a raw `MINITENSOR_TRACE_CAPACITY` value: a positive span count
/// (floored at 8, like [`set_ring_capacity`]). Zero or unparseable warns
/// once on stderr and returns `None` — it used to be ignored silently.
fn env_ring_capacity(raw: Option<&str>) -> Option<usize> {
    super::envvar::parse::<usize>(
        "MINITENSOR_TRACE_CAPACITY",
        raw,
        |&n| n > 0,
        "a positive span count",
    )
    .map(|n| n.max(8))
}

/// A named synthetic timeline track (rendered as its own "thread" in the
/// trace viewer) for spans that don't belong to any OS thread — e.g. the
/// serve stack's per-request lifecycle track. Idempotent per name.
pub fn virtual_track(name: &'static str) -> u32 {
    let mut v = VTRACKS.lock().unwrap();
    if let Some(&(_, id)) = v.iter().find(|(n, _)| *n == name) {
        return id;
    }
    let id = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    v.push((name, id));
    id
}

fn register_ring() -> Arc<RingHandle> {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let name = std::thread::current()
        .name()
        .unwrap_or("unnamed")
        .to_string();
    let cap = ring_capacity();
    let h = Arc::new(RingHandle {
        tid,
        name,
        data: Mutex::new(RingData {
            spans: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
        }),
    });
    REGISTRY.lock().unwrap().push(h.clone());
    h
}

fn push_event(mut ev: Event) {
    RING.with(|slot| {
        let mut slot = slot.borrow_mut();
        let h = slot.get_or_insert_with(register_ring);
        if ev.track == 0 {
            ev.track = h.tid;
        }
        let mut d = h.data.lock().unwrap();
        if d.spans.len() < d.cap {
            d.spans.push(ev);
        } else if d.cap > 0 {
            let head = d.head;
            d.spans[head] = ev;
            d.head = (head + 1) % d.cap;
            d.dropped += 1;
        }
    });
}

fn rel_ns(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_nanos() as u64
}

/// RAII span: records `[construction, drop]` on the calling thread's
/// ring. When tracing is disabled the guard is inert — no clock read, no
/// ring touch — and the arg setters are no-ops.
pub struct SpanGuard {
    start: Option<Instant>,
    cat: &'static str,
    name: &'static str,
    args: Args,
    n_args: u8,
}

/// Open a span; it closes (and records) when the guard drops.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard {
            start: None,
            cat,
            name,
            args: NO_ARGS,
            n_args: 0,
        };
    }
    SpanGuard {
        start: Some(Instant::now()),
        cat,
        name,
        args: NO_ARGS,
        n_args: 0,
    }
}

impl SpanGuard {
    #[inline]
    fn push_arg(&mut self, key: &'static str, val: ArgVal) {
        if self.start.is_none() {
            return;
        }
        let n = self.n_args as usize;
        if n < self.args.len() {
            self.args[n] = (key, val);
            self.n_args += 1;
        }
    }

    /// Tag the span with an integer arg (no-op when tracing is off).
    #[inline]
    pub fn arg_u(&mut self, key: &'static str, val: u64) {
        self.push_arg(key, ArgVal::U(val));
    }

    /// Tag the span with a static-string arg (no-op when tracing is off).
    #[inline]
    pub fn arg_s(&mut self, key: &'static str, val: &'static str) {
        self.push_arg(key, ArgVal::S(val));
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let dur_ns = t0.elapsed().as_nanos() as u64;
            push_event(Event {
                cat: self.cat,
                name: self.name,
                t0_ns: rel_ns(t0),
                dur_ns,
                track: 0,
                args: self.args,
            });
        }
    }
}

/// Record a span for an interval measured with explicit instants (the
/// serve stack measures request phases across threads this way).
/// `track` 0 places the span on the calling thread; a [`virtual_track`]
/// id places it on that synthetic track. At most three args are kept.
pub fn record_interval(
    track: u32,
    cat: &'static str,
    name: &'static str,
    start: Instant,
    end: Instant,
    args: &[(&'static str, ArgVal)],
) {
    if !enabled() {
        return;
    }
    let mut a = NO_ARGS;
    for (i, &kv) in args.iter().take(a.len()).enumerate() {
        a[i] = kv;
    }
    push_event(Event {
        cat,
        name,
        t0_ns: rel_ns(start),
        dur_ns: end.saturating_duration_since(start).as_nanos() as u64,
        track,
        args: a,
    });
}

/// Snapshot every ring's spans (oldest first per ring) without clearing.
pub fn events() -> Vec<Event> {
    let reg = REGISTRY.lock().unwrap();
    let mut out = Vec::new();
    for h in reg.iter() {
        let d = h.data.lock().unwrap();
        if d.spans.len() == d.cap {
            out.extend_from_slice(&d.spans[d.head..]);
            out.extend_from_slice(&d.spans[..d.head]);
        } else {
            out.extend_from_slice(&d.spans);
        }
    }
    out
}

/// Drop all recorded spans and reset the overwrite counters. Rings stay
/// registered (their buffers are reused by the next span).
pub fn clear() {
    let reg = REGISTRY.lock().unwrap();
    for h in reg.iter() {
        let mut d = h.data.lock().unwrap();
        d.spans.clear();
        d.head = 0;
        d.dropped = 0;
    }
}

/// Total spans lost to ring overwrite since the last [`clear`].
pub fn dropped() -> u64 {
    let reg = REGISTRY.lock().unwrap();
    reg.iter().map(|h| h.data.lock().unwrap().dropped).sum()
}

/// `(track id, display name)` for every registered thread ring and
/// virtual track — the trace's thread-name metadata.
pub fn track_names() -> Vec<(u32, String)> {
    let mut out: Vec<(u32, String)> = REGISTRY
        .lock()
        .unwrap()
        .iter()
        .map(|h| (h.tid, h.name.clone()))
        .collect();
    out.extend(
        VTRACKS
            .lock()
            .unwrap()
            .iter()
            .map(|&(n, id)| (id, n.to_string())),
    );
    out.sort_by_key(|&(id, _)| id);
    out
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Serialize everything recorded so far as Chrome trace-event JSON
/// (`ph:"X"` complete events, microsecond timestamps), loadable in
/// `chrome://tracing` and <https://ui.perfetto.dev>.
pub fn chrome_trace_json() -> String {
    let mut evs = events();
    evs.sort_by_key(|e| (e.t0_ns, std::cmp::Reverse(e.dur_ns)));
    let mut s = String::with_capacity(256 + evs.len() * 160);
    // Top-level metadata (`otherData` is the Chrome trace-event escape
    // hatch for tool-specific keys): a truncated trace says so in-band —
    // `droppedSpans` > 0 means the rings overwrote that many spans and
    // the timeline's left edge is incomplete.
    s.push_str(&format!(
        "{{\"displayTimeUnit\":\"ms\",\"otherData\":{{\"droppedSpans\":{},\"ringCapacity\":{}}},\"traceEvents\":[\n",
        dropped(),
        ring_capacity()
    ));
    s.push_str(
        "{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"name\":\"process_name\",\
         \"args\":{\"name\":\"minitensor\"}}",
    );
    for (tid, name) in track_names() {
        s.push_str(&format!(
            ",\n{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\""
        ));
        escape_into(&mut s, &name);
        s.push_str("\"}}");
    }
    for e in &evs {
        s.push_str(",\n{\"ph\":\"X\",\"pid\":1,\"tid\":");
        s.push_str(&e.track.to_string());
        s.push_str(",\"cat\":\"");
        escape_into(&mut s, e.cat);
        s.push_str("\",\"name\":\"");
        escape_into(&mut s, e.name);
        s.push_str(&format!(
            "\",\"ts\":{:.3},\"dur\":{:.3}",
            e.t0_ns as f64 / 1e3,
            e.dur_ns as f64 / 1e3
        ));
        let tags: Vec<_> = e.args.iter().filter(|(k, _)| !k.is_empty()).collect();
        if !tags.is_empty() {
            s.push_str(",\"args\":{");
            for (i, (k, v)) in tags.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push('"');
                escape_into(&mut s, k);
                s.push_str("\":");
                match v {
                    ArgVal::U(n) => s.push_str(&n.to_string()),
                    ArgVal::S(t) => {
                        s.push('"');
                        escape_into(&mut s, t);
                        s.push('"');
                    }
                }
            }
            s.push('}');
        }
        s.push('}');
    }
    s.push_str("\n]}\n");
    s
}

/// Write the Chrome trace to `path`; returns the number of span events.
pub fn write_chrome_trace<P: AsRef<std::path::Path>>(path: P) -> std::io::Result<usize> {
    let n = events().len();
    std::fs::write(path, chrome_trace_json())?;
    Ok(n)
}

/// If tracing came from `MINITENSOR_TRACE=<path>`, write the trace there
/// and return the path and span count.
pub fn flush_env() -> std::io::Result<Option<(String, usize)>> {
    match env_path() {
        Some(p) if enabled() || !events().is_empty() => {
            let n = write_chrome_trace(&p)?;
            Ok(Some((p, n)))
        }
        _ => Ok(None),
    }
}

/// Top-K spans by total recorded time, as a report block to print next
/// to `runtime::stats::report()`.
pub fn summary_top(k: usize) -> String {
    use std::collections::HashMap;
    let evs = events();
    if evs.is_empty() {
        return "trace:  no spans recorded\n".to_string();
    }
    let mut agg: HashMap<(&'static str, &'static str), (u64, u64, u64)> = HashMap::new();
    for e in &evs {
        let a = agg.entry((e.cat, e.name)).or_insert((0, 0, 0));
        a.0 += 1;
        a.1 += e.dur_ns;
        a.2 = a.2.max(e.dur_ns);
    }
    let mut rows: Vec<_> = agg.into_iter().collect();
    rows.sort_by_key(|&(_, (_, total, _))| std::cmp::Reverse(total));
    rows.truncate(k);
    // Always state the overwrite count (even when zero) so a summary is
    // self-describing about whether it covers the full window.
    let mut s = format!(
        "trace:  {} spans across {} tracks, {} overwritten (top {} by total time)\n",
        evs.len(),
        track_names().len(),
        dropped(),
        rows.len()
    );
    for ((cat, name), (count, total, max)) in rows {
        s.push_str(&format!(
            "  {:<28} count={:<7} total={:>9.3}ms  mean={:>8.1}us  max={:>8.1}us\n",
            format!("{cat}.{name}"),
            count,
            total as f64 / 1e6,
            total as f64 / 1e3 / count as f64,
            max as f64 / 1e3,
        ));
    }
    let lost = dropped();
    if lost > 0 {
        s.push_str(&format!(
            "  ({lost} spans overwritten — raise MINITENSOR_TRACE_CAPACITY to keep more)\n"
        ));
    }
    s
}

/// [`summary_top`] with the default K of 12.
pub fn summary() -> String {
    summary_top(12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        // Regardless of global state, an inert guard records nothing and
        // its arg setters are no-ops.
        let mut g = SpanGuard {
            start: None,
            cat: "t",
            name: "t",
            args: NO_ARGS,
            n_args: 0,
        };
        g.arg_u("k", 1);
        assert_eq!(g.n_args, 0);
    }

    #[test]
    fn args_cap_at_three() {
        let mut g = SpanGuard {
            start: Some(Instant::now()),
            cat: "t",
            name: "t",
            args: NO_ARGS,
            n_args: 0,
        };
        g.arg_u("a", 1);
        g.arg_u("b", 2);
        g.arg_s("c", "x");
        g.arg_u("d", 4); // dropped
        assert_eq!(g.n_args, 3);
        assert_eq!(g.args[2], ("c", ArgVal::S("x")));
        g.start = None; // don't record into the shared rings from a unit test
    }

    #[test]
    fn virtual_tracks_are_idempotent() {
        let a = virtual_track("test.track");
        let b = virtual_track("test.track");
        assert_eq!(a, b);
        assert!(track_names().iter().any(|(id, n)| *id == a && n == "test.track"));
    }

    #[test]
    fn json_escapes_control_chars() {
        let mut s = String::new();
        escape_into(&mut s, "a\"b\\c\nd\u{1}");
        assert_eq!(s, "a\\\"b\\\\c\\nd\\u0001");
    }

    #[test]
    fn env_ring_capacity_rejects_zero_and_garbage() {
        // Pure resolution over raw values — no std::env mutation (the
        // test harness is multi-threaded).
        assert_eq!(env_ring_capacity(None), None);
        assert_eq!(env_ring_capacity(Some("4096")), Some(4096));
        assert_eq!(env_ring_capacity(Some("3")), Some(8), "floored at 8");
        // Zero would make every ring drop every span; it warns and falls
        // back instead of being silently filtered like before.
        assert_eq!(env_ring_capacity(Some("0")), None);
        assert_eq!(env_ring_capacity(Some("lots")), None);
        assert_eq!(env_ring_capacity(Some("-1")), None);
        let err = crate::runtime::envvar::parse_checked::<usize>(
            "MINITENSOR_TRACE_CAPACITY",
            Some("0"),
            |&n| n > 0,
            "a positive span count",
        )
        .unwrap_err();
        assert!(err.contains("MINITENSOR_TRACE_CAPACITY"), "{err}");
    }

    #[test]
    fn chrome_json_carries_dropped_metadata() {
        let json = chrome_trace_json();
        assert!(json.contains("\"otherData\":{\"droppedSpans\":"), "{json}");
        assert!(json.contains("\"ringCapacity\":"), "{json}");
    }

    #[test]
    fn summary_always_states_overwrite_count() {
        // Even with nothing recorded the summary must be self-describing;
        // with spans, the header carries the overwritten count.
        let s = summary();
        assert!(
            s.contains("no spans recorded") || s.contains("overwritten"),
            "{s}"
        );
    }
}
