//! Shapes, strides, and NumPy/PyTorch broadcasting (paper §3.1).
//!
//! A tensor is an n-dimensional array with shape `s = (s_1, …, s_n)` and a
//! contiguous row-major layout by default; views carry explicit strides.
//! Broadcasting follows the NumPy rule: shapes are right-aligned, and two
//! dimensions are compatible when they are equal or one of them is 1. A
//! broadcast dimension of size 1 is *virtually* expanded by giving it
//! stride 0 — the engine never materializes the expansion, exactly as the
//! paper describes for `x + b` with `x ∈ R^{b×d}`, `b ∈ R^d`.

use crate::error::{Error, Result};

/// Shape of a tensor: dimension sizes, row-major.
///
/// Rank 0 (scalar) is represented by an empty dims vector and has one
/// element.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape {
    dims: Vec<usize>,
}

impl Shape {
    /// Build a shape from a slice of dimension sizes.
    pub fn new(dims: &[usize]) -> Shape {
        Shape {
            dims: dims.to_vec(),
        }
    }

    /// Scalar shape (rank 0, one element).
    pub fn scalar() -> Shape {
        Shape { dims: Vec::new() }
    }

    /// Dimension sizes.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total number of elements (product of dims; 1 for scalars).
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Size along `axis`, supporting negative (from-the-end) indexing.
    pub fn dim(&self, axis: isize) -> Result<usize> {
        let ax = self.normalize_axis(axis)?;
        Ok(self.dims[ax])
    }

    /// Convert a possibly-negative axis into a concrete index.
    pub fn normalize_axis(&self, axis: isize) -> Result<usize> {
        let rank = self.rank() as isize;
        let ax = if axis < 0 { axis + rank } else { axis };
        if ax < 0 || ax >= rank {
            return Err(Error::AxisOutOfRange {
                axis,
                rank: self.rank(),
            });
        }
        Ok(ax as usize)
    }

    /// Contiguous row-major strides (in elements, not bytes).
    pub fn contiguous_strides(&self) -> Vec<isize> {
        let mut strides = vec![0isize; self.rank()];
        let mut acc = 1isize;
        for (i, &d) in self.dims.iter().enumerate().rev() {
            strides[i] = acc;
            acc *= d as isize;
        }
        strides
    }

    /// Broadcast two shapes under the NumPy rule, returning the result
    /// shape. Errors when any right-aligned dimension pair disagrees and
    /// neither side is 1.
    pub fn broadcast(&self, other: &Shape) -> Result<Shape> {
        let r = self.rank().max(other.rank());
        let mut out = vec![0usize; r];
        for i in 0..r {
            let a = self.dim_right_aligned(i, r);
            let b = other.dim_right_aligned(i, r);
            out[i] = match (a, b) {
                (x, y) if x == y => x,
                (1, y) => y,
                (x, 1) => x,
                _ => {
                    return Err(Error::BroadcastMismatch {
                        lhs: self.dims.clone(),
                        rhs: other.dims.clone(),
                    })
                }
            };
        }
        Ok(Shape::new(&out))
    }

    /// Dimension `i` of this shape when right-aligned to total rank `r`
    /// (missing leading dims read as 1).
    fn dim_right_aligned(&self, i: usize, r: usize) -> usize {
        let pad = r - self.rank();
        if i < pad {
            1
        } else {
            self.dims[i - pad]
        }
    }

    /// Strides for *reading this shape as if it were `target`*: broadcast
    /// dimensions get stride 0 (the virtual expansion of §3.1).
    ///
    /// `base` holds this tensor's actual strides. `target` must be a valid
    /// broadcast of `self`.
    pub fn broadcast_strides(&self, base: &[isize], target: &Shape) -> Result<Vec<isize>> {
        if target.rank() < self.rank() {
            return Err(Error::BroadcastMismatch {
                lhs: self.dims.clone(),
                rhs: target.dims.clone(),
            });
        }
        let pad = target.rank() - self.rank();
        let mut out = vec![0isize; target.rank()];
        for i in 0..target.rank() {
            if i < pad {
                out[i] = 0;
            } else {
                let own = self.dims[i - pad];
                let tgt = target.dims[i];
                out[i] = if own == tgt {
                    base[i - pad]
                } else if own == 1 {
                    0
                } else {
                    return Err(Error::BroadcastMismatch {
                        lhs: self.dims.clone(),
                        rhs: target.dims.clone(),
                    });
                };
            }
        }
        Ok(out)
    }

    /// The axes along which `self` was expanded to reach `target`
    /// (including padded leading axes). These are exactly the axes a
    /// gradient must be summed over in the broadcast pullback.
    pub fn broadcast_reduce_axes(&self, target: &Shape) -> Vec<usize> {
        let pad = target.rank() - self.rank();
        let mut axes = Vec::new();
        for i in 0..target.rank() {
            if i < pad {
                axes.push(i);
            } else if self.dims[i - pad] == 1 && target.dims[i] != 1 {
                axes.push(i);
            }
        }
        axes
    }
}

impl From<&[usize]> for Shape {
    fn from(d: &[usize]) -> Shape {
        Shape::new(d)
    }
}

impl From<Vec<usize>> for Shape {
    fn from(d: Vec<usize>) -> Shape {
        Shape { dims: d }
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, ")")
    }
}

/// Iterator over the multi-dimensional indices of a shape in row-major
/// order, yielding the linear offset under a given stride vector.
///
/// This is the strided fallback path for non-contiguous tensors; contiguous
/// tensors take bulk slice kernels instead (see `ops::kernels`).
pub struct StridedIter {
    dims: Vec<usize>,
    strides: Vec<isize>,
    index: Vec<usize>,
    offset: isize,
    remaining: usize,
}

impl StridedIter {
    /// Iterate `shape` using `strides`, starting at element offset `offset`.
    pub fn new(shape: &Shape, strides: &[isize], offset: isize) -> StridedIter {
        StridedIter {
            dims: shape.dims().to_vec(),
            strides: strides.to_vec(),
            index: vec![0; shape.rank()],
            offset,
            remaining: shape.numel(),
        }
    }

    /// Like [`StridedIter::new`], but positioned at row-major logical
    /// index `linear` (yields `numel - linear` offsets). This is what lets
    /// the execution layer split one strided walk across worker chunks
    /// without replaying the odometer from zero.
    pub fn starting_at(
        shape: &Shape,
        strides: &[isize],
        offset: isize,
        linear: usize,
    ) -> StridedIter {
        let dims = shape.dims().to_vec();
        let mut index = vec![0usize; dims.len()];
        let mut off = offset;
        let mut rem = linear;
        for ax in (0..dims.len()).rev() {
            let d = dims[ax].max(1);
            index[ax] = rem % d;
            rem /= d;
            off += index[ax] as isize * strides[ax];
        }
        StridedIter {
            dims,
            strides: strides.to_vec(),
            index,
            offset: off,
            remaining: shape.numel().saturating_sub(linear),
        }
    }
}

impl Iterator for StridedIter {
    type Item = isize;

    fn next(&mut self) -> Option<isize> {
        if self.remaining == 0 {
            return None;
        }
        let current = self.offset;
        self.remaining -= 1;
        // Advance the odometer from the innermost axis.
        for ax in (0..self.dims.len()).rev() {
            self.index[ax] += 1;
            self.offset += self.strides[ax];
            if self.index[ax] < self.dims[ax] {
                break;
            }
            self.offset -= self.strides[ax] * self.dims[ax] as isize;
            self.index[ax] = 0;
        }
        Some(current)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for StridedIter {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_strides_row_major() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.contiguous_strides(), vec![12, 4, 1]);
        assert_eq!(Shape::scalar().contiguous_strides(), Vec::<isize>::new());
    }

    #[test]
    fn numel_and_rank() {
        assert_eq!(Shape::new(&[2, 3, 4]).numel(), 24);
        assert_eq!(Shape::scalar().numel(), 1);
        assert_eq!(Shape::new(&[0, 5]).numel(), 0);
        assert_eq!(Shape::new(&[2, 3]).rank(), 2);
    }

    #[test]
    fn broadcast_basic() {
        let a = Shape::new(&[4, 1]);
        let b = Shape::new(&[3]);
        assert_eq!(a.broadcast(&b).unwrap(), Shape::new(&[4, 3]));
        // paper's example: (b, d) + (d,)
        let x = Shape::new(&[32, 10]);
        let bias = Shape::new(&[10]);
        assert_eq!(x.broadcast(&bias).unwrap(), Shape::new(&[32, 10]));
    }

    #[test]
    fn broadcast_scalar() {
        let a = Shape::new(&[2, 2]);
        assert_eq!(a.broadcast(&Shape::scalar()).unwrap(), a);
    }

    #[test]
    fn broadcast_mismatch_errors() {
        let a = Shape::new(&[3, 2]);
        let b = Shape::new(&[4, 2]);
        assert!(matches!(
            a.broadcast(&b),
            Err(Error::BroadcastMismatch { .. })
        ));
    }

    #[test]
    fn broadcast_strides_zero_on_expanded_axes() {
        let b = Shape::new(&[3]);
        let target = Shape::new(&[4, 3]);
        let strides = b.broadcast_strides(&[1], &target).unwrap();
        assert_eq!(strides, vec![0, 1]);
    }

    #[test]
    fn broadcast_reduce_axes_identifies_summed_dims() {
        let b = Shape::new(&[3]);
        let target = Shape::new(&[4, 3]);
        assert_eq!(b.broadcast_reduce_axes(&target), vec![0]);

        let k = Shape::new(&[1, 3]);
        assert_eq!(k.broadcast_reduce_axes(&target), vec![0]);

        let full = Shape::new(&[4, 3]);
        assert!(full.broadcast_reduce_axes(&target).is_empty());
    }

    #[test]
    fn negative_axis_normalization() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.normalize_axis(-1).unwrap(), 2);
        assert_eq!(s.normalize_axis(-3).unwrap(), 0);
        assert!(s.normalize_axis(3).is_err());
        assert!(s.normalize_axis(-4).is_err());
    }

    #[test]
    fn strided_iter_visits_row_major() {
        let s = Shape::new(&[2, 3]);
        let offsets: Vec<isize> = StridedIter::new(&s, &[3, 1], 0).collect();
        assert_eq!(offsets, vec![0, 1, 2, 3, 4, 5]);
        // transposed view: strides swapped
        let t: Vec<isize> = StridedIter::new(&Shape::new(&[3, 2]), &[1, 3], 0).collect();
        assert_eq!(t, vec![0, 3, 1, 4, 2, 5]);
    }

    #[test]
    fn strided_iter_broadcast_stride_zero() {
        let s = Shape::new(&[2, 3]);
        let offsets: Vec<isize> = StridedIter::new(&s, &[0, 1], 0).collect();
        assert_eq!(offsets, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn starting_at_matches_skip() {
        let s = Shape::new(&[3, 2, 4]);
        let strides = [8isize, 4, 1]; // contiguous
        let t_strides = [1isize, 12, 3]; // arbitrary permuted view
        for strides in [&strides, &t_strides] {
            for skip in [0usize, 1, 5, 11, 23, 24] {
                let want: Vec<isize> =
                    StridedIter::new(&s, strides.as_slice(), 2).skip(skip).collect();
                let got: Vec<isize> =
                    StridedIter::starting_at(&s, strides.as_slice(), 2, skip).collect();
                assert_eq!(got, want, "skip={skip} strides={strides:?}");
            }
        }
    }
}
