//! Tensor constructors: zeros/ones/full/arange/linspace/eye/from_vec and
//! random initializers (uniform/normal via the engine RNG).

use super::{Storage, Tensor};
use crate::data::Rng;
use crate::dtype::DType;
use crate::error::{Error, Result};
use crate::shape::Shape;

impl Tensor {
    /// Build a tensor from a flat row-major buffer and a shape.
    pub fn from_vec(data: Vec<f32>, dims: &[usize]) -> Result<Tensor> {
        let shape = Shape::new(dims);
        if data.len() != shape.numel() {
            return Err(Error::ReshapeNumel {
                numel: data.len(),
                target: dims.to_vec(),
            });
        }
        let strides = shape.contiguous_strides();
        Ok(Tensor::from_parts(
            Storage::from_vec(data),
            shape,
            strides,
            0,
            DType::F32,
        ))
    }

    /// Build an i32-tagged tensor (labels / indices).
    pub fn from_vec_i32(data: Vec<i32>, dims: &[usize]) -> Result<Tensor> {
        let f: Vec<f32> = data.into_iter().map(|v| v as f32).collect();
        Ok(Tensor::from_vec(f, dims)?.with_dtype(DType::I32))
    }

    /// Rank-0 scalar tensor.
    pub fn scalar(value: f32) -> Tensor {
        Tensor::from_parts(
            Storage::from_vec(vec![value]),
            Shape::scalar(),
            Vec::new(),
            0,
            DType::F32,
        )
    }

    /// All-zeros tensor.
    pub fn zeros(dims: &[usize]) -> Tensor {
        Tensor::full(dims, 0.0)
    }

    /// All-ones tensor.
    pub fn ones(dims: &[usize]) -> Tensor {
        Tensor::full(dims, 1.0)
    }

    /// Constant-filled tensor.
    pub fn full(dims: &[usize], value: f32) -> Tensor {
        let shape = Shape::new(dims);
        let strides = shape.contiguous_strides();
        Tensor::from_parts(
            Storage::full(shape.numel(), value),
            shape,
            strides,
            0,
            DType::F32,
        )
    }

    /// Zeros with the same shape as `other`.
    pub fn zeros_like(other: &Tensor) -> Tensor {
        Tensor::zeros(other.dims())
    }

    /// Ones with the same shape as `other`.
    pub fn ones_like(other: &Tensor) -> Tensor {
        Tensor::ones(other.dims())
    }

    /// `[start, stop)` with unit step, 1-D.
    pub fn arange(start: f32, stop: f32) -> Tensor {
        Tensor::arange_step(start, stop, 1.0)
    }

    /// `[start, stop)` with the given step, 1-D.
    pub fn arange_step(start: f32, stop: f32, step: f32) -> Tensor {
        assert!(step != 0.0, "arange step must be nonzero");
        let n = if (stop - start) / step > 0.0 {
            ((stop - start) / step).ceil() as usize
        } else {
            0
        };
        let data: Vec<f32> = (0..n).map(|i| start + i as f32 * step).collect();
        Tensor::from_vec(data, &[n]).expect("arange shape always matches")
    }

    /// `n` evenly spaced points over `[start, stop]`, 1-D.
    pub fn linspace(start: f32, stop: f32, n: usize) -> Tensor {
        let data: Vec<f32> = if n <= 1 {
            vec![start]
        } else {
            let step = (stop - start) / (n - 1) as f32;
            (0..n).map(|i| start + i as f32 * step).collect()
        };
        let len = data.len();
        Tensor::from_vec(data, &[len]).expect("linspace shape always matches")
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Tensor {
        let mut data = vec![0.0; n * n];
        for i in 0..n {
            data[i * n + i] = 1.0;
        }
        Tensor::from_vec(data, &[n, n]).expect("eye shape always matches")
    }

    /// Uniform samples in `[lo, hi)`.
    pub fn rand(dims: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Tensor {
        let shape = Shape::new(dims);
        let data: Vec<f32> = (0..shape.numel())
            .map(|_| lo + (hi - lo) * rng.next_f32())
            .collect();
        Tensor::from_vec(data, dims).expect("rand shape always matches")
    }

    /// Standard-normal samples scaled by `std` around `mean`.
    pub fn randn(dims: &[usize], mean: f32, std: f32, rng: &mut Rng) -> Tensor {
        let shape = Shape::new(dims);
        let data: Vec<f32> = (0..shape.numel())
            .map(|_| mean + std * rng.next_normal())
            .collect();
        Tensor::from_vec(data, dims).expect("randn shape always matches")
    }

    /// One-hot encode a 1-D i32 label tensor into `[n, classes]`.
    pub fn one_hot(labels: &Tensor, classes: usize) -> Result<Tensor> {
        if labels.rank() != 1 {
            return Err(Error::ShapeMismatch {
                op: "one_hot",
                expected: "rank-1 labels".into(),
                got: format!("rank {}", labels.rank()),
            });
        }
        let n = labels.numel();
        let mut data = vec![0.0; n * classes];
        for (i, v) in labels.iter().enumerate() {
            let c = v as usize;
            if c >= classes {
                return Err(Error::IndexOutOfBounds {
                    index: c,
                    size: classes,
                });
            }
            data[i * classes + c] = 1.0;
        }
        Tensor::from_vec(data, &[n, classes])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates_numel() {
        assert!(Tensor::from_vec(vec![1.0; 5], &[2, 3]).is_err());
        assert!(Tensor::from_vec(vec![1.0; 6], &[2, 3]).is_ok());
    }

    #[test]
    fn fills() {
        assert_eq!(Tensor::zeros(&[2, 2]).to_vec(), vec![0.0; 4]);
        assert_eq!(Tensor::ones(&[3]).to_vec(), vec![1.0; 3]);
        assert_eq!(Tensor::full(&[2], -2.5).to_vec(), vec![-2.5, -2.5]);
    }

    #[test]
    fn arange_and_linspace() {
        assert_eq!(Tensor::arange(0.0, 4.0).to_vec(), vec![0., 1., 2., 3.]);
        assert_eq!(Tensor::arange_step(1.0, 0.0, -0.5).to_vec(), vec![1.0, 0.5]);
        assert_eq!(Tensor::linspace(0.0, 1.0, 3).to_vec(), vec![0.0, 0.5, 1.0]);
        assert_eq!(Tensor::linspace(2.0, 9.0, 1).to_vec(), vec![2.0]);
    }

    #[test]
    fn eye_diagonal() {
        let e = Tensor::eye(3);
        assert_eq!(e.at(&[1, 1]).unwrap(), 1.0);
        assert_eq!(e.at(&[0, 2]).unwrap(), 0.0);
        assert_eq!(e.to_vec().iter().sum::<f32>(), 3.0);
    }

    #[test]
    fn rand_within_bounds_and_deterministic() {
        let mut rng = Rng::new(42);
        let t = Tensor::rand(&[100], -1.0, 1.0, &mut rng);
        assert!(t.iter().all(|v| (-1.0..1.0).contains(&v)));
        let mut rng2 = Rng::new(42);
        let t2 = Tensor::rand(&[100], -1.0, 1.0, &mut rng2);
        assert_eq!(t.to_vec(), t2.to_vec());
    }

    #[test]
    fn randn_moments_roughly_standard() {
        let mut rng = Rng::new(7);
        let t = Tensor::randn(&[10000], 0.0, 1.0, &mut rng);
        let v = t.to_vec();
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn one_hot_encodes() {
        let labels = Tensor::from_vec_i32(vec![0, 2, 1], &[3]).unwrap();
        let oh = Tensor::one_hot(&labels, 3).unwrap();
        assert_eq!(oh.dims(), &[3, 3]);
        assert_eq!(oh.to_vec(), vec![1., 0., 0., 0., 0., 1., 0., 1., 0.]);
        let bad = Tensor::from_vec_i32(vec![5], &[1]).unwrap();
        assert!(Tensor::one_hot(&bad, 3).is_err());
    }
}
