//! Pretty printing of tensors, NumPy-style with truncation for large
//! tensors.

use super::Tensor;

/// Maximum elements per dimension shown before eliding with `...`.
const EDGE_ITEMS: usize = 3;
/// Tensors at or under this numel print in full.
const FULL_PRINT_LIMIT: usize = 64;

impl std::fmt::Display for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let truncate = self.numel() > FULL_PRINT_LIMIT;
        fmt_rec(self, f, &mut Vec::new(), truncate)?;
        write!(f, " {}{}", self.dtype(), self.shape())
    }
}

fn fmt_rec(
    t: &Tensor,
    f: &mut std::fmt::Formatter<'_>,
    index: &mut Vec<usize>,
    truncate: bool,
) -> std::fmt::Result {
    let depth = index.len();
    if depth == t.rank() {
        let v = t.at(index).map_err(|_| std::fmt::Error)?;
        return write!(f, "{v:.4}");
    }
    let dim = t.dims()[depth];
    write!(f, "[")?;
    let mut printed_ellipsis = false;
    for i in 0..dim {
        let elide = truncate && dim > 2 * EDGE_ITEMS && i >= EDGE_ITEMS && i < dim - EDGE_ITEMS;
        if elide {
            if !printed_ellipsis {
                write!(f, ", ...")?;
                printed_ellipsis = true;
            }
            continue;
        }
        if i > 0 {
            write!(f, ", ")?;
        }
        index.push(i);
        fmt_rec(t, f, index, truncate)?;
        index.pop();
    }
    write!(f, "]")
}

#[cfg(test)]
mod tests {
    use crate::tensor::Tensor;

    #[test]
    fn scalar_display() {
        let s = format!("{}", Tensor::scalar(1.5));
        assert!(s.contains("1.5000"), "{s}");
        assert!(s.contains("float32"), "{s}");
    }

    #[test]
    fn matrix_display_nested_brackets() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]).unwrap();
        let s = format!("{t}");
        assert!(s.starts_with("[[1.0000, 2.0000], [3.0000, 4.0000]]"), "{s}");
        assert!(s.contains("(2, 2)"), "{s}");
    }

    #[test]
    fn large_tensor_truncates() {
        let t = Tensor::zeros(&[100]);
        let s = format!("{t}");
        assert!(s.contains("..."), "{s}");
        assert!(s.len() < 200, "{s}");
    }

    #[test]
    fn int_dtype_shown() {
        let t = Tensor::from_vec_i32(vec![1, 2], &[2]).unwrap();
        let s = format!("{t}");
        assert!(s.contains("int32"), "{s}");
    }
}
