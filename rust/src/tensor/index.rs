//! Indexing operations: row gather, index-select, masked select, and the
//! scatter-add adjoint that backs the Embedding layer's pullback.

use super::Tensor;
use crate::error::{Error, Result};

impl Tensor {
    /// Gather rows (axis 0) by an i32 index tensor: `out[i, …] =
    /// self[idx[i], …]`. This is the embedding-lookup primitive.
    pub fn index_select0(&self, idx: &Tensor) -> Result<Tensor> {
        if idx.rank() != 1 {
            return Err(Error::ShapeMismatch {
                op: "index_select0",
                expected: "rank-1 index tensor".into(),
                got: format!("rank {}", idx.rank()),
            });
        }
        let n_rows = self.dims()[0];
        let row: usize = self.dims()[1..].iter().product();
        let src = self.contiguous();
        let s = src.contiguous_data().unwrap();
        let mut out = Vec::with_capacity(idx.numel() * row);
        for v in idx.iter() {
            let i = v as usize;
            if i >= n_rows {
                return Err(Error::IndexOutOfBounds {
                    index: i,
                    size: n_rows,
                });
            }
            out.extend_from_slice(&s[i * row..(i + 1) * row]);
        }
        let mut dims = self.dims().to_vec();
        dims[0] = idx.numel();
        Tensor::from_vec(out, &dims)
    }

    /// Scatter-add rows of `src` into a zeros tensor of `n_rows` rows:
    /// `out[idx[i], …] += src[i, …]`. The adjoint of [`Tensor::index_select0`].
    pub fn scatter_add0(src: &Tensor, idx: &Tensor, n_rows: usize) -> Result<Tensor> {
        if idx.rank() != 1 || idx.numel() != src.dims()[0] {
            return Err(Error::ShapeMismatch {
                op: "scatter_add0",
                expected: format!("rank-1 index of length {}", src.dims()[0]),
                got: format!("{:?}", idx.dims()),
            });
        }
        let row: usize = src.dims()[1..].iter().product();
        let sc = src.contiguous();
        let s = sc.contiguous_data().unwrap();
        let mut out = vec![0.0f32; n_rows * row];
        for (i, v) in idx.iter().enumerate() {
            let r = v as usize;
            if r >= n_rows {
                return Err(Error::IndexOutOfBounds {
                    index: r,
                    size: n_rows,
                });
            }
            for j in 0..row {
                out[r * row + j] += s[i * row + j];
            }
        }
        let mut dims = src.dims().to_vec();
        dims[0] = n_rows;
        Tensor::from_vec(out, &dims)
    }

    /// Keep elements where `mask != 0`, flattened to 1-D.
    pub fn masked_select(&self, mask: &Tensor) -> Result<Tensor> {
        if self.dims() != mask.dims() {
            return Err(Error::ShapeMismatch {
                op: "masked_select",
                expected: format!("mask of shape {:?}", self.dims()),
                got: format!("{:?}", mask.dims()),
            });
        }
        let out: Vec<f32> = self
            .iter()
            .zip(mask.iter())
            .filter(|(_, m)| *m != 0.0)
            .map(|(v, _)| v)
            .collect();
        let n = out.len();
        Tensor::from_vec(out, &[n])
    }

    /// Indices (as i32 tensor) where `self != 0`, flattened order.
    pub fn nonzero(&self) -> Tensor {
        let out: Vec<f32> = self
            .iter()
            .enumerate()
            .filter(|(_, v)| *v != 0.0)
            .map(|(i, _)| i as f32)
            .collect();
        let n = out.len();
        Tensor::from_vec(out, &[n])
            .expect("nonzero shape always matches")
            .with_dtype(crate::DType::I32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_select_rows() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[3, 2]).unwrap();
        let idx = Tensor::from_vec_i32(vec![2, 0, 2], &[3]).unwrap();
        let out = t.index_select0(&idx).unwrap();
        assert_eq!(out.dims(), &[3, 2]);
        assert_eq!(out.to_vec(), vec![5., 6., 1., 2., 5., 6.]);
        let bad = Tensor::from_vec_i32(vec![7], &[1]).unwrap();
        assert!(t.index_select0(&bad).is_err());
    }

    #[test]
    fn scatter_add_is_adjoint_of_gather() {
        // <gather(W, idx), G> == <W, scatter(G, idx)> for random data.
        let w = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[3, 2]).unwrap();
        let idx = Tensor::from_vec_i32(vec![1, 1, 0], &[3]).unwrap();
        let g = Tensor::from_vec(vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6], &[3, 2]).unwrap();
        let gathered = w.index_select0(&idx).unwrap();
        let lhs: f32 = gathered
            .to_vec()
            .iter()
            .zip(g.to_vec())
            .map(|(a, b)| a * b)
            .sum();
        let scattered = Tensor::scatter_add0(&g, &idx, 3).unwrap();
        let rhs: f32 = w
            .to_vec()
            .iter()
            .zip(scattered.to_vec())
            .map(|(a, b)| a * b)
            .sum();
        assert!((lhs - rhs).abs() < 1e-5);
    }

    #[test]
    fn scatter_add_accumulates_duplicates() {
        let src = Tensor::ones(&[3, 1]);
        let idx = Tensor::from_vec_i32(vec![0, 0, 1], &[3]).unwrap();
        let out = Tensor::scatter_add0(&src, &idx, 2).unwrap();
        assert_eq!(out.to_vec(), vec![2.0, 1.0]);
    }

    #[test]
    fn masked_select_and_nonzero() {
        let t = Tensor::from_vec(vec![1., -2., 0., 4.], &[2, 2]).unwrap();
        let mask = t.gt(&Tensor::zeros(&[2, 2])).unwrap();
        let sel = t.masked_select(&mask).unwrap();
        assert_eq!(sel.to_vec(), vec![1., 4.]);
        let nz = t.nonzero();
        assert_eq!(nz.to_vec(), vec![0., 1., 3.]);
        assert!(t.masked_select(&Tensor::zeros(&[4])).is_err());
    }
}
