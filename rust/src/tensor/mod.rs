//! Dense n-dimensional tensors (paper §3.1).
//!
//! A [`Tensor`] is a shape + strides + offset view over a shared
//! [`Storage`] buffer. Freshly constructed tensors are contiguous
//! row-major; views produced by `reshape`/`transpose`/`slice`/
//! `broadcast_to` share the buffer and only rewrite metadata.

mod construct;
mod display;
mod index;
pub mod pool;
mod storage;
mod view;

pub use storage::Storage;

use crate::dtype::DType;
use crate::error::Result;
use crate::shape::{Shape, StridedIter};

/// Dense n-dimensional array over f32-backed storage.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub(crate) storage: Storage,
    pub(crate) shape: Shape,
    pub(crate) strides: Vec<isize>,
    pub(crate) offset: isize,
    pub(crate) dtype: DType,
}

impl Tensor {
    /// Assemble a tensor from raw parts. `strides` must address only valid
    /// elements of `storage` for every index of `shape` — callers inside
    /// the crate uphold this.
    pub(crate) fn from_parts(
        storage: Storage,
        shape: Shape,
        strides: Vec<isize>,
        offset: isize,
        dtype: DType,
    ) -> Tensor {
        Tensor {
            storage,
            shape,
            strides,
            offset,
            dtype,
        }
    }

    /// The tensor's shape.
    #[inline]
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Dimension sizes as a slice.
    #[inline]
    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Number of dimensions.
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    /// Total element count.
    #[inline]
    pub fn numel(&self) -> usize {
        self.shape.numel()
    }

    /// Element dtype tag.
    #[inline]
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Strides in elements (not bytes).
    #[inline]
    pub fn strides(&self) -> &[isize] {
        &self.strides
    }

    /// True when the view is contiguous row-major starting at its offset.
    pub fn is_contiguous(&self) -> bool {
        self.strides == self.shape.contiguous_strides()
    }

    /// Fast path: the underlying storage slice for a contiguous view.
    /// Returns `None` for strided/broadcast views.
    #[inline]
    pub fn contiguous_data(&self) -> Option<&[f32]> {
        if self.is_contiguous() {
            let start = self.offset as usize;
            Some(&self.storage.as_slice()[start..start + self.numel()])
        } else {
            None
        }
    }

    /// Iterate element values in row-major logical order (works for any
    /// view; prefer [`Tensor::contiguous_data`] in kernels).
    pub fn iter(&self) -> impl Iterator<Item = f32> + '_ {
        let data = self.storage.as_slice();
        StridedIter::new(&self.shape, &self.strides, self.offset).map(move |o| data[o as usize])
    }

    /// Materialize the logical contents into a fresh `Vec<f32>` in
    /// row-major order.
    pub fn to_vec(&self) -> Vec<f32> {
        match self.contiguous_data() {
            Some(s) => s.to_vec(),
            None => self.iter().collect(),
        }
    }

    /// Return self if contiguous, otherwise copy into a contiguous tensor.
    pub fn contiguous(&self) -> Tensor {
        if self.is_contiguous() {
            self.clone()
        } else {
            Tensor::from_parts(
                Storage::from_vec(self.to_vec()),
                self.shape.clone(),
                self.shape.contiguous_strides(),
                0,
                self.dtype,
            )
        }
    }

    /// Read a single element by multi-dimensional index.
    pub fn at(&self, index: &[usize]) -> Result<f32> {
        if index.len() != self.rank() {
            return Err(crate::Error::ShapeMismatch {
                op: "at",
                expected: format!("index of rank {}", self.rank()),
                got: format!("rank {}", index.len()),
            });
        }
        let mut off = self.offset;
        for (ax, (&i, &d)) in index.iter().zip(self.dims()).enumerate() {
            if i >= d {
                return Err(crate::Error::IndexOutOfBounds { index: i, size: d });
            }
            off += i as isize * self.strides[ax];
        }
        Ok(self.storage.as_slice()[off as usize])
    }

    /// Extract the value of a one-element tensor.
    pub fn item(&self) -> Result<f32> {
        if self.numel() != 1 {
            return Err(crate::Error::ShapeMismatch {
                op: "item",
                expected: "1 element".into(),
                got: format!("{} elements", self.numel()),
            });
        }
        Ok(self.iter().next().unwrap())
    }

    /// Retag the dtype without touching data (values must already be valid
    /// for the target dtype; comparisons produce exact 0.0/1.0 etc.).
    pub fn with_dtype(mut self, dtype: DType) -> Tensor {
        self.dtype = dtype;
        self
    }

    /// Whether two tensors share the same storage allocation.
    pub fn shares_storage(&self, other: &Tensor) -> bool {
        self.storage.ptr_eq(&other.storage)
    }

    /// Approximate equality between two tensors (shape equal, all elements
    /// within `atol + rtol*|b|`). The workhorse of the test suite.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.iter()
            .zip(other.iter())
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguity_and_to_vec() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap();
        assert!(t.is_contiguous());
        assert_eq!(t.to_vec(), vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose(0, 1).unwrap();
        assert!(!tt.is_contiguous());
        assert_eq!(tt.to_vec(), vec![1., 4., 2., 5., 3., 6.]);
        assert!(tt.contiguous().is_contiguous());
    }

    #[test]
    fn at_and_item() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]).unwrap();
        assert_eq!(t.at(&[1, 0]).unwrap(), 3.0);
        assert!(t.at(&[2, 0]).is_err());
        assert!(t.at(&[0]).is_err());
        assert!(t.item().is_err());
        assert_eq!(Tensor::scalar(5.0).item().unwrap(), 5.0);
    }

    #[test]
    fn views_share_storage() {
        let t = Tensor::zeros(&[4, 4]);
        let v = t.reshape(&[16]).unwrap();
        assert!(t.shares_storage(&v));
        let c = v.contiguous();
        assert!(c.shares_storage(&t)); // already contiguous: no copy
    }

    #[test]
    fn allclose_detects_mismatch() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]).unwrap();
        let b = Tensor::from_vec(vec![1.0, 2.0 + 1e-7], &[2]).unwrap();
        assert!(a.allclose(&b, 1e-5, 1e-6));
        let c = Tensor::from_vec(vec![1.0, 3.0], &[2]).unwrap();
        assert!(!a.allclose(&c, 1e-5, 1e-6));
        let d = Tensor::from_vec(vec![1.0, 2.0], &[2, 1]).unwrap();
        assert!(!a.allclose(&d, 1e-5, 1e-6));
    }
}
