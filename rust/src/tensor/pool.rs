//! Thread-local buffer pool for tensor storage.
//!
//! Large elementwise chains allocate and free one output buffer per op;
//! above ~L2 size every allocation becomes a fresh kernel mapping whose
//! pages are zeroed and soft-faulted on first touch — that, not the
//! arithmetic, dominated the 1M-element benchmarks (EXPERIMENTS.md §Perf
//! L3.2). The pool recycles the backing `Vec<f32>`s: [`Storage`] returns
//! its buffer here when the last reference drops, and the bulk ops
//! request buffers from here instead of the allocator.
//!
//! Telemetry: every request and return feeds the process-wide registry
//! (`minitensor_pool_{hits,misses,returns}_total`,
//! `minitensor_pool_bytes_pooled`, `minitensor_pool_bytes_highwater`) —
//! the hit rate is `hits / (hits + misses)`. The pools are per-thread,
//! so the high-water mark is the largest footprint any single thread's
//! pool has reached.
//!
//! [`Storage`]: super::Storage

use std::cell::RefCell;

use crate::runtime::metrics::{self, Id};

/// Keep at most this many buffers per thread.
const MAX_POOLED: usize = 16;
/// Don't pool buffers smaller than this (allocator handles them fine).
const MIN_BYTES: usize = 1 << 14; // 16 KiB
/// Cap on total pooled bytes per thread.
const MAX_TOTAL_BYTES: usize = 256 << 20; // 256 MiB

thread_local! {
    static POOL: RefCell<Pool> = const {
        RefCell::new(Pool {
            buffers: Vec::new(),
            total_bytes: 0,
        })
    };
}

struct Pool {
    buffers: Vec<Vec<f32>>,
    total_bytes: usize,
}

/// Get a cleared buffer with at least `capacity` elements of capacity.
/// Reuses a pooled buffer when one fits; the contents are cleared, so
/// callers `extend`/`push` into it without any zero-fill pass.
pub fn take(capacity: usize) -> Vec<f32> {
    try_take(capacity).unwrap_or_else(|| Vec::with_capacity(capacity))
}

/// Like [`take`], but returns `None` instead of allocating on a pool
/// miss — callers that have a cheaper fresh-allocation path (e.g.
/// `vec![0.0; n]`, which gets lazily-zeroed pages from the OS) use this
/// to only pay the recycle cost when there is something to recycle.
///
/// Selection is best-fit (smallest pooled buffer that is large enough),
/// so a small long-lived tensor does not pin a giant recycled buffer.
pub fn try_take(capacity: usize) -> Option<Vec<f32>> {
    // `pool.alloc` failpoint: `error` degrades to a forced miss (the
    // caller's fresh-allocation fallback is the recovery path under
    // test), `delay_ms` stalls the allocation, `panic` panics.
    if crate::runtime::faults::armed() {
        use crate::runtime::faults::{check, FaultKind};
        match check("pool.alloc") {
            None => {}
            Some(FaultKind::Error) => {
                metrics::add(Id::PoolMisses, 1);
                return None;
            }
            Some(FaultKind::DelayMs(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms))
            }
            Some(FaultKind::Panic) => panic!("minitensor: injected fault at pool.alloc"),
        }
    }
    let took = POOL.with(|p| {
        let mut p = p.borrow_mut();
        let best = p
            .buffers
            .iter()
            .enumerate()
            .filter(|(_, v)| v.capacity() >= capacity)
            .min_by_key(|(_, v)| v.capacity())
            .map(|(i, _)| i)?;
        let mut v = p.buffers.swap_remove(best);
        p.total_bytes -= v.capacity() * 4;
        v.clear();
        Some(v)
    });
    match &took {
        Some(v) => {
            metrics::add(Id::PoolHits, 1);
            metrics::gauge_add(Id::PoolBytesPooled, -((v.capacity() * 4) as i64));
        }
        None => metrics::add(Id::PoolMisses, 1),
    }
    took
}

/// Return a buffer to the pool (no-op for small or overflow buffers).
pub fn put(v: Vec<f32>) {
    let bytes = v.capacity() * 4;
    if bytes < MIN_BYTES {
        return;
    }
    let pooled_total = POOL.with(|p| {
        let mut p = p.borrow_mut();
        if p.buffers.len() < MAX_POOLED && p.total_bytes + bytes <= MAX_TOTAL_BYTES {
            p.total_bytes += bytes;
            p.buffers.push(v);
            Some(p.total_bytes)
        } else {
            None
        }
    });
    if let Some(total) = pooled_total {
        metrics::add(Id::PoolReturns, 1);
        metrics::gauge_add(Id::PoolBytesPooled, bytes as i64);
        metrics::gauge_peak(Id::PoolBytesHighwater, total as u64);
    }
}

/// Number of buffers currently pooled on this thread (for tests).
pub fn pooled_count() -> usize {
    POOL.with(|p| p.borrow().buffers.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_reuses_allocation() {
        let v = take(10_000);
        assert!(v.capacity() >= 10_000);
        let ptr = v.as_ptr();
        put(v);
        let v2 = take(10_000);
        assert_eq!(v2.as_ptr(), ptr, "should reuse the pooled buffer");
        assert!(v2.is_empty());
        put(v2);
    }

    #[test]
    fn small_buffers_not_pooled() {
        let before = pooled_count();
        put(Vec::with_capacity(8));
        assert_eq!(pooled_count(), before);
    }

    #[test]
    fn take_larger_than_pooled_allocates_fresh() {
        put(Vec::with_capacity(10_000));
        let v = take(1_000_000);
        assert!(v.capacity() >= 1_000_000);
    }

    #[test]
    fn take_prefers_best_fit() {
        // A small request must not pin the big pooled buffer.
        let big = Vec::with_capacity(1 << 20);
        let small = Vec::with_capacity(8192);
        let small_ptr = small.as_ptr();
        put(big);
        put(small);
        let v = try_take(5000).expect("a pooled buffer fits");
        assert_eq!(v.as_ptr(), small_ptr, "best-fit should pick the 8K buffer");
        assert!(try_take(1 << 21).is_none(), "nothing big enough pooled");
    }

    #[test]
    fn pool_traffic_feeds_the_registry() {
        // Exercise a hit, a miss, and a return on a fresh thread (its own
        // shard), then check the merged registry moved by at least that
        // much — other test threads can only add more.
        let grab = |s: &metrics::MetricsSnapshot, name: &str| {
            s.counters
                .iter()
                .find(|(k, _)| k == name)
                .map(|&(_, v)| v)
                .unwrap_or(0)
        };
        let before = metrics::snapshot();
        std::thread::spawn(|| {
            let v = take(10_000); // miss (fresh thread pool is empty)
            put(v); // return
            let v2 = try_take(10_000).expect("hit");
            drop(v2);
        })
        .join()
        .unwrap();
        let after = metrics::snapshot();
        assert!(grab(&after, "minitensor_pool_misses_total") > grab(&before, "minitensor_pool_misses_total"));
        assert!(grab(&after, "minitensor_pool_returns_total") > grab(&before, "minitensor_pool_returns_total"));
        assert!(grab(&after, "minitensor_pool_hits_total") > grab(&before, "minitensor_pool_hits_total"));
        let hw = after
            .gauges
            .iter()
            .find(|(k, _)| k == "minitensor_pool_bytes_highwater")
            .map(|&(_, v)| v)
            .unwrap_or(0.0);
        assert!(hw >= 40_000.0, "10k-f32 return must register: {hw}");
    }
}
