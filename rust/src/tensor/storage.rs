//! Reference-counted flat buffers backing tensors.
//!
//! The paper's engine stores "a typed buffer and lightweight metadata"
//! (§3.1). `Storage` is that buffer: a flat `Vec<f32>` behind an `Arc` so
//! views (reshape/transpose/slice/broadcast) share memory with zero copies.
//! Gradient buffers are *not* allocated here eagerly — the autograd tape
//! delays them until a backward pass needs them (§3.5).

use std::sync::Arc;

use crate::runtime::metrics::{self, Id};

/// Shared, immutable-once-shared flat buffer of f32 elements.
///
/// Mutation is only allowed through [`Storage::make_mut`], which performs
/// copy-on-write when the buffer is shared — this gives eager PyTorch-like
/// in-place semantics without aliasing bugs.
///
/// When the last reference drops, the backing buffer is recycled through
/// the thread-local [`pool`](super::pool) instead of returning to the
/// allocator (large-tensor hot-loop optimization, EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct Storage {
    data: Arc<Vec<f32>>,
}

impl Drop for Storage {
    fn drop(&mut self) {
        // Last owner: salvage the allocation for the pool.
        if let Some(data) = Arc::get_mut(&mut self.data) {
            metrics::gauge_add(Id::PoolBytesLive, -((data.capacity() * 4) as i64));
            super::pool::put(std::mem::take(data));
        }
    }
}

impl Storage {
    /// Take ownership of a buffer. The sole construction path, so the
    /// live-bytes gauge (`minitensor_pool_bytes_live`) counts every
    /// allocation exactly once; the matching decrement is in the
    /// last-owner `Drop` branch.
    pub fn from_vec(data: Vec<f32>) -> Storage {
        metrics::gauge_add(Id::PoolBytesLive, (data.capacity() * 4) as i64);
        Storage {
            data: Arc::new(data),
        }
    }

    /// Allocate `n` zeroed elements. Recycles a buffer from the
    /// thread-local [`pool`](super::pool) when one fits (best-fit), so
    /// zero-construction in hot loops (gradients, optimizer state) stops
    /// hitting the allocator; on a pool miss it falls back to `vec!`,
    /// which gets lazily-zeroed pages straight from the OS.
    pub fn zeros(n: usize) -> Storage {
        Storage::full(n, 0.0)
    }

    /// Allocate `n` elements of `value` (pool-backed, see [`Storage::zeros`]).
    pub fn full(n: usize, value: f32) -> Storage {
        match super::pool::try_take(n) {
            Some(mut v) => {
                v.resize(n, value);
                Storage::from_vec(v)
            }
            None => Storage::from_vec(vec![value; n]),
        }
    }

    /// Read access to the raw buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Number of elements in the underlying buffer (may exceed the numel of
    /// a view into it).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Mutable access with copy-on-write: if another tensor shares this
    /// buffer the data is cloned first, so in-place ops never alias.
    pub fn make_mut(&mut self) -> &mut [f32] {
        // COW detection for the live-bytes gauge: `Arc::make_mut` clones
        // behind our back when shared, which bypasses `from_vec`. A
        // changed data pointer is the exact, race-free signal (the old
        // allocation stays live in the other owners and keeps its count).
        let before = self.data.as_ptr();
        let data = Arc::make_mut(&mut self.data);
        if data.as_ptr() != before {
            metrics::gauge_add(Id::PoolBytesLive, (data.capacity() * 4) as i64);
        }
        data.as_mut_slice()
    }

    /// Whether two storages share the same allocation (used by tests to
    /// assert zero-copy view behaviour).
    pub fn ptr_eq(&self, other: &Storage) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_is_zero_copy() {
        let a = Storage::from_vec(vec![1.0, 2.0]);
        let b = a.clone();
        assert!(a.ptr_eq(&b));
    }

    #[test]
    fn make_mut_copies_on_write_when_shared() {
        let mut a = Storage::from_vec(vec![1.0, 2.0]);
        let b = a.clone();
        a.make_mut()[0] = 9.0;
        assert!(!a.ptr_eq(&b));
        assert_eq!(b.as_slice()[0], 1.0);
        assert_eq!(a.as_slice()[0], 9.0);
    }

    #[test]
    fn make_mut_in_place_when_unique() {
        let mut a = Storage::from_vec(vec![1.0]);
        let p = a.as_slice().as_ptr();
        a.make_mut()[0] = 5.0;
        assert_eq!(a.as_slice().as_ptr(), p);
    }

    #[test]
    fn constructors() {
        assert_eq!(Storage::zeros(3).as_slice(), &[0.0, 0.0, 0.0]);
        assert_eq!(Storage::full(2, 7.5).as_slice(), &[7.5, 7.5]);
        assert!(Storage::from_vec(vec![]).is_empty());
    }

    #[test]
    fn zeros_recycles_pooled_buffers() {
        // A pool-eligible buffer (≥ MIN_BYTES) must be reused by zeros()
        // and come back fully cleared.
        let n = 10_000;
        let mut dirty = super::super::pool::take(n);
        dirty.resize(n, 3.5);
        let ptr = dirty.as_ptr();
        super::super::pool::put(dirty);
        let s = Storage::zeros(n);
        assert_eq!(s.as_slice().as_ptr(), ptr, "should reuse the pooled buffer");
        assert!(s.as_slice().iter().all(|&v| v == 0.0));
        assert_eq!(s.len(), n);
    }
}
