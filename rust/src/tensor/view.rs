//! Zero-copy view operations: reshape, permute, transpose, slice, squeeze,
//! unsqueeze, broadcast_to, narrow. All of these only rewrite metadata
//! (shape/strides/offset) and share the underlying storage when possible —
//! the "lightweight metadata" design of paper §3.1.

use super::Tensor;
use crate::error::{Error, Result};
use crate::shape::Shape;

impl Tensor {
    /// Reinterpret the tensor with a new shape of the same numel.
    ///
    /// A single `-1`-style inferred dimension is supported via
    /// [`Tensor::reshape_infer`]. Contiguous tensors reshape with zero
    /// copies; strided views fall back to one materializing copy.
    pub fn reshape(&self, dims: &[usize]) -> Result<Tensor> {
        let target = Shape::new(dims);
        if target.numel() != self.numel() {
            return Err(Error::ReshapeNumel {
                numel: self.numel(),
                target: dims.to_vec(),
            });
        }
        let base = if self.is_contiguous() {
            self.clone()
        } else {
            self.contiguous()
        };
        Ok(Tensor::from_parts(
            base.storage.clone(),
            target.clone(),
            target.contiguous_strides(),
            base.offset,
            self.dtype,
        ))
    }

    /// Reshape where at most one entry may be `-1` (inferred).
    pub fn reshape_infer(&self, dims: &[isize]) -> Result<Tensor> {
        let neg = dims.iter().filter(|&&d| d == -1).count();
        if neg > 1 {
            return Err(Error::msg("reshape: at most one dimension may be -1"));
        }
        let known: usize = dims.iter().filter(|&&d| d != -1).map(|&d| d as usize).product();
        let resolved: Vec<usize> = dims
            .iter()
            .map(|&d| {
                if d == -1 {
                    if known == 0 {
                        0
                    } else {
                        self.numel() / known
                    }
                } else {
                    d as usize
                }
            })
            .collect();
        self.reshape(&resolved)
    }

    /// Flatten to 1-D.
    pub fn flatten(&self) -> Result<Tensor> {
        self.reshape(&[self.numel()])
    }

    /// Permute dimensions. `perm` must be a permutation of `0..rank`.
    pub fn permute(&self, perm: &[usize]) -> Result<Tensor> {
        if perm.len() != self.rank() {
            return Err(Error::ShapeMismatch {
                op: "permute",
                expected: format!("permutation of length {}", self.rank()),
                got: format!("length {}", perm.len()),
            });
        }
        let mut seen = vec![false; self.rank()];
        for &p in perm {
            if p >= self.rank() || seen[p] {
                return Err(Error::msg(format!("permute: invalid permutation {perm:?}")));
            }
            seen[p] = true;
        }
        let dims: Vec<usize> = perm.iter().map(|&p| self.dims()[p]).collect();
        let strides: Vec<isize> = perm.iter().map(|&p| self.strides[p]).collect();
        Ok(Tensor::from_parts(
            self.storage.clone(),
            Shape::new(&dims),
            strides,
            self.offset,
            self.dtype,
        ))
    }

    /// Swap two axes (negative axes allowed).
    pub fn transpose(&self, a: isize, b: isize) -> Result<Tensor> {
        let a = self.shape.normalize_axis(a)?;
        let b = self.shape.normalize_axis(b)?;
        let mut perm: Vec<usize> = (0..self.rank()).collect();
        perm.swap(a, b);
        self.permute(&perm)
    }

    /// Matrix transpose of a 2-D tensor.
    pub fn t(&self) -> Result<Tensor> {
        if self.rank() != 2 {
            return Err(Error::ShapeMismatch {
                op: "t",
                expected: "rank 2".into(),
                got: format!("rank {}", self.rank()),
            });
        }
        self.transpose(0, 1)
    }

    /// Remove all size-1 dimensions (or a specific one with
    /// [`Tensor::squeeze_axis`]).
    pub fn squeeze(&self) -> Tensor {
        let mut dims = Vec::new();
        let mut strides = Vec::new();
        for (i, &d) in self.dims().iter().enumerate() {
            if d != 1 {
                dims.push(d);
                strides.push(self.strides[i]);
            }
        }
        Tensor::from_parts(
            self.storage.clone(),
            Shape::new(&dims),
            strides,
            self.offset,
            self.dtype,
        )
    }

    /// Remove one size-1 dimension.
    pub fn squeeze_axis(&self, axis: isize) -> Result<Tensor> {
        let ax = self.shape.normalize_axis(axis)?;
        if self.dims()[ax] != 1 {
            return Err(Error::ShapeMismatch {
                op: "squeeze_axis",
                expected: "dimension of size 1".into(),
                got: format!("size {}", self.dims()[ax]),
            });
        }
        let mut dims = self.dims().to_vec();
        let mut strides = self.strides.clone();
        dims.remove(ax);
        strides.remove(ax);
        Ok(Tensor::from_parts(
            self.storage.clone(),
            Shape::new(&dims),
            strides,
            self.offset,
            self.dtype,
        ))
    }

    /// Insert a size-1 dimension at `axis` (0..=rank).
    pub fn unsqueeze(&self, axis: isize) -> Result<Tensor> {
        let rank = self.rank() as isize;
        let ax = if axis < 0 { axis + rank + 1 } else { axis };
        if ax < 0 || ax > rank {
            return Err(Error::AxisOutOfRange {
                axis,
                rank: self.rank(),
            });
        }
        let ax = ax as usize;
        let mut dims = self.dims().to_vec();
        let mut strides = self.strides.clone();
        dims.insert(ax, 1);
        strides.insert(ax, 0);
        Ok(Tensor::from_parts(
            self.storage.clone(),
            Shape::new(&dims),
            strides,
            self.offset,
            self.dtype,
        ))
    }

    /// Zero-copy broadcast view to `target` (stride-0 on expanded axes).
    pub fn broadcast_to(&self, dims: &[usize]) -> Result<Tensor> {
        let target = Shape::new(dims);
        let strides = self.shape.broadcast_strides(&self.strides, &target)?;
        Ok(Tensor::from_parts(
            self.storage.clone(),
            target,
            strides,
            self.offset,
            self.dtype,
        ))
    }

    /// View of `len` indices starting at `start` along `axis`.
    pub fn narrow(&self, axis: isize, start: usize, len: usize) -> Result<Tensor> {
        let ax = self.shape.normalize_axis(axis)?;
        let size = self.dims()[ax];
        if start + len > size {
            return Err(Error::IndexOutOfBounds {
                index: start + len,
                size,
            });
        }
        let mut dims = self.dims().to_vec();
        dims[ax] = len;
        Ok(Tensor::from_parts(
            self.storage.clone(),
            Shape::new(&dims),
            self.strides.clone(),
            self.offset + start as isize * self.strides[ax],
            self.dtype,
        ))
    }

    /// Select one index along `axis`, dropping that axis.
    pub fn select(&self, axis: isize, index: usize) -> Result<Tensor> {
        let ax = self.shape.normalize_axis(axis)?;
        self.narrow(axis, index, 1)?.squeeze_axis(ax as isize)
    }

    /// Row `i` of a rank-≥1 tensor (alias for `select(0, i)`).
    pub fn row(&self, i: usize) -> Result<Tensor> {
        self.select(0, i)
    }

    /// Concatenate tensors along `axis` (copies; not a view).
    pub fn cat(tensors: &[&Tensor], axis: isize) -> Result<Tensor> {
        if tensors.is_empty() {
            return Err(Error::msg("cat: need at least one tensor"));
        }
        let first = tensors[0];
        let ax = first.shape.normalize_axis(axis)?;
        let mut cat_dim = 0usize;
        for t in tensors {
            if t.rank() != first.rank() {
                return Err(Error::ShapeMismatch {
                    op: "cat",
                    expected: format!("rank {}", first.rank()),
                    got: format!("rank {}", t.rank()),
                });
            }
            for (i, (&a, &b)) in t.dims().iter().zip(first.dims()).enumerate() {
                if i != ax && a != b {
                    return Err(Error::ShapeMismatch {
                        op: "cat",
                        expected: format!("{:?} (except axis {ax})", first.dims()),
                        got: format!("{:?}", t.dims()),
                    });
                }
            }
            cat_dim += t.dims()[ax];
        }
        let mut out_dims = first.dims().to_vec();
        out_dims[ax] = cat_dim;
        let out_shape = Shape::new(&out_dims);

        // Copy slice-by-slice: iterate the leading (pre-axis) index space,
        // and for each, append each tensor's trailing block.
        let lead: usize = first.dims()[..ax].iter().product();
        let mut data = Vec::with_capacity(out_shape.numel());
        let contigs: Vec<Tensor> = tensors.iter().map(|t| t.contiguous()).collect();
        for l in 0..lead {
            for t in &contigs {
                let tail: usize = t.dims()[ax..].iter().product();
                let s = t.contiguous_data().unwrap();
                data.extend_from_slice(&s[l * tail..(l + 1) * tail]);
            }
        }
        Tensor::from_vec(data, &out_dims)
    }

    /// Stack tensors of identical shape along a new leading axis.
    pub fn stack(tensors: &[&Tensor], axis: isize) -> Result<Tensor> {
        let unsq: Vec<Tensor> = tensors
            .iter()
            .map(|t| t.unsqueeze(axis))
            .collect::<Result<_>>()?;
        let refs: Vec<&Tensor> = unsq.iter().collect();
        Tensor::cat(&refs, axis)
    }

    /// Split into equal chunks along `axis`.
    pub fn chunk(&self, chunks: usize, axis: isize) -> Result<Vec<Tensor>> {
        let ax = self.shape.normalize_axis(axis)?;
        let size = self.dims()[ax];
        if chunks == 0 || size % chunks != 0 {
            return Err(Error::msg(format!(
                "chunk: cannot split size {size} into {chunks} equal chunks"
            )));
        }
        let step = size / chunks;
        (0..chunks)
            .map(|i| self.narrow(ax as isize, i * step, step))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t23() -> Tensor {
        Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]).unwrap()
    }

    #[test]
    fn reshape_zero_copy_when_contiguous() {
        let t = t23();
        let r = t.reshape(&[3, 2]).unwrap();
        assert!(t.shares_storage(&r));
        assert_eq!(r.to_vec(), t.to_vec());
        assert!(t.reshape(&[7]).is_err());
    }

    #[test]
    fn reshape_infer() {
        let t = t23();
        assert_eq!(t.reshape_infer(&[-1]).unwrap().dims(), &[6]);
        assert_eq!(t.reshape_infer(&[3, -1]).unwrap().dims(), &[3, 2]);
        assert!(t.reshape_infer(&[-1, -1]).is_err());
    }

    #[test]
    fn permute_and_transpose() {
        let t = t23();
        let p = t.permute(&[1, 0]).unwrap();
        assert_eq!(p.dims(), &[3, 2]);
        assert_eq!(p.at(&[2, 1]).unwrap(), 6.0);
        assert!(t.permute(&[0, 0]).is_err());
        assert!(t.permute(&[0]).is_err());
        let tt = t.t().unwrap();
        assert_eq!(tt.to_vec(), vec![1., 4., 2., 5., 3., 6.]);
        assert!(Tensor::zeros(&[2]).t().is_err());
    }

    #[test]
    fn squeeze_unsqueeze_roundtrip() {
        let t = Tensor::zeros(&[1, 3, 1]);
        assert_eq!(t.squeeze().dims(), &[3]);
        assert_eq!(t.squeeze_axis(0).unwrap().dims(), &[3, 1]);
        assert!(t.squeeze_axis(1).is_err());
        let u = t.squeeze().unsqueeze(0).unwrap();
        assert_eq!(u.dims(), &[1, 3]);
        let v = t.squeeze().unsqueeze(-1).unwrap();
        assert_eq!(v.dims(), &[3, 1]);
    }

    #[test]
    fn broadcast_to_is_zero_copy() {
        let b = Tensor::from_vec(vec![1., 2., 3.], &[3]).unwrap();
        let big = b.broadcast_to(&[4, 3]).unwrap();
        assert!(b.shares_storage(&big));
        assert_eq!(big.numel(), 12);
        assert_eq!(big.at(&[3, 2]).unwrap(), 3.0);
        assert!(b.broadcast_to(&[4, 5]).is_err());
    }

    #[test]
    fn narrow_select_row() {
        let t = t23();
        let n = t.narrow(1, 1, 2).unwrap();
        assert_eq!(n.to_vec(), vec![2., 3., 5., 6.]);
        assert!(t.narrow(1, 2, 2).is_err());
        let r = t.row(1).unwrap();
        assert_eq!(r.to_vec(), vec![4., 5., 6.]);
        let c = t.select(1, 0).unwrap();
        assert_eq!(c.to_vec(), vec![1., 4.]);
    }

    #[test]
    fn cat_and_stack() {
        let a = Tensor::from_vec(vec![1., 2.], &[1, 2]).unwrap();
        let b = Tensor::from_vec(vec![3., 4.], &[1, 2]).unwrap();
        let c = Tensor::cat(&[&a, &b], 0).unwrap();
        assert_eq!(c.dims(), &[2, 2]);
        assert_eq!(c.to_vec(), vec![1., 2., 3., 4.]);
        let d = Tensor::cat(&[&a, &b], 1).unwrap();
        assert_eq!(d.dims(), &[1, 4]);

        let x = Tensor::from_vec(vec![1., 2.], &[2]).unwrap();
        let y = Tensor::from_vec(vec![3., 4.], &[2]).unwrap();
        let s = Tensor::stack(&[&x, &y], 0).unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.to_vec(), vec![1., 2., 3., 4.]);

        let bad = Tensor::zeros(&[2, 3]);
        assert!(Tensor::cat(&[&a, &bad], 0).is_err());
    }

    #[test]
    fn chunk_splits_evenly() {
        let t = Tensor::arange(0.0, 6.0).reshape(&[6, 1]).unwrap();
        let parts = t.chunk(3, 0).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[1].to_vec(), vec![2., 3.]);
        assert!(t.chunk(4, 0).is_err());
    }
}
