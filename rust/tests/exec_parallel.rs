//! Integration tests for the unified kernel-execution layer:
//! broadcasting edge cases that the tier dispatch must survive (empty
//! tensors, zero-length bias rows, strided fallbacks) and
//! parallel-vs-serial equivalence for every kernel family migrated onto
//! the worker pool (`MINITENSOR_NUM_THREADS=1` vs `=4` semantics via
//! `runtime::parallel::set_num_threads`) — forward **and** backward: the
//! conv2d pullbacks, attention end-to-end, and the strided unary walk are
//! pinned bit-identical across thread counts, with finite-difference
//! gradchecks run under parallel dispatch.

use std::sync::{Mutex, MutexGuard, OnceLock};

use minitensor::autograd::{gradcheck, Var};
use minitensor::data::Rng;
use minitensor::ops::conv::{conv2d_backward_input, conv2d_backward_weight};
use minitensor::ops::softmax::cross_entropy_forward;
use minitensor::ops::{
    attention_backward, attention_forward, avg_pool2d, conv2d, max_pool2d, Conv2dSpec,
};
use minitensor::runtime::parallel;
use minitensor::tensor::Tensor;

/// The thread count is process-global: tests that flip it serialize here.
fn nt_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Run `f` once at 1 thread and once at 4, returning both results.
fn serial_vs_parallel<T>(f: impl Fn() -> T) -> (T, T) {
    let before = parallel::num_threads();
    parallel::set_num_threads(1);
    let serial = f();
    parallel::set_num_threads(4);
    let par = f();
    parallel::set_num_threads(before);
    (serial, par)
}

// ---------------------------------------------------------------------
// Broadcasting / tier-dispatch edge cases
// ---------------------------------------------------------------------

#[test]
fn empty_tensors_broadcast_to_empty() {
    let a = Tensor::from_vec(Vec::new(), &[0]).unwrap();
    let b = Tensor::from_vec(Vec::new(), &[0]).unwrap();
    let y = a.add(&b).unwrap();
    assert_eq!(y.dims(), &[0]);
    assert_eq!(y.numel(), 0);

    let m = Tensor::from_vec(Vec::new(), &[2, 0]).unwrap();
    let v = Tensor::from_vec(Vec::new(), &[0]).unwrap();
    // k = 0 bias row: must dispatch to the empty result, not chunk by 0.
    let y = m.add(&v).unwrap();
    assert_eq!(y.dims(), &[2, 0]);

    let w = Tensor::from_vec(Vec::new(), &[0, 3]).unwrap();
    let bias = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3]).unwrap();
    let y = w.add(&bias).unwrap();
    assert_eq!(y.dims(), &[0, 3]);
    assert_eq!(y.numel(), 0);
}

#[test]
fn empty_unary_softmax_and_reduce() {
    let m = Tensor::from_vec(Vec::new(), &[2, 0]).unwrap();
    assert_eq!(m.relu().dims(), &[2, 0]);
    assert_eq!(m.softmax().unwrap().dims(), &[2, 0]);
    // Reducing an empty axis yields the reduction identity per output.
    let s = m.sum_axis(1, false).unwrap();
    assert_eq!(s.dims(), &[2]);
    assert_eq!(s.to_vec(), vec![0.0, 0.0]);
    let mx = m.max_axis(1, false).unwrap();
    assert_eq!(mx.to_vec(), vec![f32::NEG_INFINITY; 2]);
    // No outputs at all.
    let z = Tensor::from_vec(Vec::new(), &[0, 5]).unwrap();
    assert_eq!(z.sum_axis(0, false).unwrap().dims(), &[5]);
    assert_eq!(z.sum_axis(1, false).unwrap().dims(), &[0]);
    // Full reduction over nothing is the identity.
    assert_eq!(m.sum().item().unwrap(), 0.0);
}

#[test]
fn non_contiguous_rhs_falls_to_strided_tier() {
    // Same shapes but a transposed RHS: tier 1 must reject it (no
    // contiguous slice) and tier 3 must produce the materialized answer.
    let mut rng = Rng::new(11);
    let a = Tensor::randn(&[6, 4], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[4, 6], 0.0, 1.0, &mut rng).t().unwrap();
    assert!(!b.is_contiguous());
    let direct = a.add(&b).unwrap();
    let via_copy = a.add(&b.contiguous()).unwrap();
    assert_eq!(direct.to_vec(), via_copy.to_vec());

    // Rank-1 RHS over a non-contiguous LHS likewise skips the row tier.
    let at = a.t().unwrap(); // [4, 6]
    let bias = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[6]).unwrap();
    let y = at.add(&bias).unwrap();
    let y_ref = at.contiguous().add(&bias).unwrap();
    assert_eq!(y.to_vec(), y_ref.to_vec());
}

// ---------------------------------------------------------------------
// Parallel-vs-serial equivalence, one test per migrated kernel family.
// Elementwise, matmul, and conv kernels keep per-element accumulation
// order, so they must match bit-for-bit at any thread count; reductions
// and the loss combine chunk partials, so they get a tight tolerance.
// ---------------------------------------------------------------------

#[test]
fn elementwise_tiers_match_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(1);
    let n = 1 << 17; // comfortably above the parallel threshold
    let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| a.mul(&b).unwrap().add(&a).unwrap().to_vec());
    assert_eq!(s, p, "tier 1 fused loop");

    let rows = Tensor::randn(&[512, 300], 0.0, 1.0, &mut rng);
    let bias = Tensor::randn(&[300], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| rows.add(&bias).unwrap().to_vec());
    assert_eq!(s, p, "tier 2 bias rows");

    let col = Tensor::randn(&[512, 1], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| rows.mul(&col).unwrap().to_vec());
    assert_eq!(s, p, "tier 3 strided broadcast");
}

#[test]
fn unary_map_matches_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(2);
    let a = Tensor::randn(&[1 << 17], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| a.gelu().to_vec());
    assert_eq!(s, p);
}

#[test]
fn softmax_family_matches_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(3);
    let logits = Tensor::randn(&[1024, 128], 0.0, 2.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| logits.softmax().unwrap().to_vec());
    assert_eq!(s, p, "softmax rows are independent");
    let (s, p) = serial_vs_parallel(|| logits.log_softmax().unwrap().to_vec());
    assert_eq!(s, p, "log_softmax rows are independent");

    let labels_vec: Vec<i32> = (0..1024).map(|i| (i % 128) as i32).collect();
    let labels = Tensor::from_vec_i32(labels_vec, &[1024]).unwrap();
    let ((ls, ps), (lp, pp)) = serial_vs_parallel(|| {
        let (loss, probs) = cross_entropy_forward(&logits, &labels).unwrap();
        (loss.item().unwrap(), probs.to_vec())
    });
    assert_eq!(ps, pp, "probs rows are independent");
    assert!((ls - lp).abs() <= 1e-4 * ls.abs(), "loss partials: {ls} vs {lp}");
}

#[test]
fn reductions_match_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(4);
    let a = Tensor::randn(&[1 << 17], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| a.sum().item().unwrap());
    assert!((s - p).abs() <= 0.05, "sum {s} vs {p}");
    let (s, p) = serial_vs_parallel(|| a.max_all().item().unwrap());
    assert_eq!(s, p, "max is order-free");

    let m = Tensor::randn(&[512, 300], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| m.sum_axis(1, false).unwrap().to_vec());
    assert_eq!(s, p, "last-axis rows keep serial order");
    let (s, p) = serial_vs_parallel(|| m.sum_axis(0, false).unwrap().to_vec());
    assert_eq!(s, p, "panel accumulation keeps serial order");

    let cube = Tensor::randn(&[32, 64, 48], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| cube.sum_axis(1, true).unwrap().to_vec());
    assert_eq!(s, p, "middle axis");
}

#[test]
fn matmul_matches_bitwise_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(5);
    // Above the 64³ small-problem cutoff so the blocked panel path runs,
    // with ragged edges in every blocking dimension.
    let a = Tensor::randn(&[161, 140], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[140, 120], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| a.matmul(&b).unwrap().to_vec());
    assert_eq!(s, p, "panel-parallel SGEMM keeps accumulation order");

    let x = Tensor::randn(&[96, 200], 0.0, 1.0, &mut rng);
    let w = Tensor::randn(&[64, 200], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| x.matmul_nt(&w).unwrap().to_vec());
    assert_eq!(s, p, "row-parallel x·Wᵀ");

    let ba = Tensor::randn(&[8, 48, 40], 0.0, 1.0, &mut rng);
    let bb = Tensor::randn(&[8, 40, 32], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| ba.matmul(&bb).unwrap().to_vec());
    assert_eq!(s, p, "batch-parallel matmul");
}

#[test]
fn conv_and_pool_match_bitwise_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(6);
    let x = Tensor::randn(&[6, 3, 20, 20], 0.0, 1.0, &mut rng);
    let w = Tensor::randn(&[8, 3, 3, 3], 0.0, 1.0, &mut rng);
    let spec = Conv2dSpec { stride: 1, padding: 1 };
    let (s, p) = serial_vs_parallel(|| conv2d(&x, &w, spec).unwrap().to_vec());
    assert_eq!(s, p, "batch-parallel conv2d");

    let (s, p) = serial_vs_parallel(|| {
        let (y, arg) = max_pool2d(&x, 2).unwrap();
        (y.to_vec(), arg)
    });
    assert_eq!(s, p, "image-parallel max_pool2d");
    let (s, p) = serial_vs_parallel(|| avg_pool2d(&x, 2).unwrap().to_vec());
    assert_eq!(s, p, "image-parallel avg_pool2d");
}

#[test]
fn strided_unary_matches_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(12);
    // Transposed view well above the parallel threshold: the tier-3
    // odometer walk chunks over the pool and must stay bit-identical.
    let base = Tensor::randn(&[300, 512], 0.0, 1.0, &mut rng);
    let view = base.t().unwrap();
    assert!(!view.is_contiguous());
    let (s, p) = serial_vs_parallel(|| view.gelu().to_vec());
    assert_eq!(s, p, "chunked tier-3 strided unary walk");
    // ... and the walk agrees with the contiguous fused loop elementwise.
    assert_eq!(s, view.contiguous().gelu().to_vec());
}

// ---------------------------------------------------------------------
// Gradient-path equivalence: the migrated backward kernels must produce
// bit-identical cotangents at any thread count. conv2d_backward_input
// and attention keep per-element accumulation order; the weight gradient
// sums per-chunk partials over a partition and combine tree that depend
// only on the batch size, never the thread count.
// ---------------------------------------------------------------------

#[test]
fn conv_backward_passes_match_bitwise_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(13);
    // Big enough that both backwards take their parallel paths.
    let x = Tensor::randn(&[6, 3, 20, 20], 0.0, 1.0, &mut rng);
    let w = Tensor::randn(&[8, 3, 3, 3], 0.0, 1.0, &mut rng);
    let spec = Conv2dSpec { stride: 1, padding: 1 };
    let y = conv2d(&x, &w, spec).unwrap();
    let g = Tensor::randn(y.dims(), 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| {
        let dx = conv2d_backward_input(&g, &w, x.dims(), spec).unwrap();
        let dw = conv2d_backward_weight(&g, &x, w.dims(), spec).unwrap();
        (dx.to_vec(), dw.to_vec())
    });
    assert_eq!(s.0, p.0, "batch-parallel conv2d_backward_input");
    assert_eq!(s.1, p.1, "fixed-partition conv2d_backward_weight");
}

#[test]
fn attention_matches_bitwise_across_thread_counts() {
    let _guard = nt_lock();
    let mut rng = Rng::new(14);
    // Above the SGEMM small-problem cutoff and the parallel threshold, so
    // QKᵀ, the softmax rows, the V mix, and every gradient product all
    // engage the pool.
    let q = Tensor::randn(&[128, 64], 0.0, 1.0, &mut rng);
    let k = Tensor::randn(&[160, 64], 0.0, 1.0, &mut rng);
    let v = Tensor::randn(&[160, 96], 0.0, 1.0, &mut rng);
    let g = Tensor::randn(&[128, 96], 0.0, 1.0, &mut rng);
    let (s, p) = serial_vs_parallel(|| {
        let (out, probs) = attention_forward(&q, &k, &v).unwrap();
        let (dq, dk, dv) = attention_backward(&g, &q, &k, &v, &probs).unwrap();
        (out.to_vec(), dq.to_vec(), dk.to_vec(), dv.to_vec())
    });
    assert_eq!(s.0, p.0, "attention forward");
    assert_eq!(s.1, p.1, "attention dq");
    assert_eq!(s.2, p.2, "attention dk");
    assert_eq!(s.3, p.3, "attention dv");
}

#[test]
fn conv_attention_net_backward_matches_bitwise_across_thread_counts() {
    let _guard = nt_lock();
    // End-to-end tape: conv → relu → reshape → self-attention → sum, so
    // `.backward()` exercises the migrated conv and attention pullbacks
    // through autograd exactly as a training step would.
    let mut rng = Rng::new(15);
    let x = Tensor::randn(&[4, 3, 12, 12], 0.0, 1.0, &mut rng);
    let w = Tensor::randn(&[8, 3, 3, 3], 0.0, 1.0, &mut rng);
    let run = || {
        let xv = Var::from_tensor(x.clone(), true);
        let wv = Var::from_tensor(w.clone(), true);
        let y = xv
            .conv2d(&wv, Conv2dSpec { stride: 1, padding: 1 })
            .unwrap()
            .relu()
            .reshape(&[4 * 8, 144])
            .unwrap();
        let out = y.attention(&y, &y).unwrap();
        out.sum().unwrap().backward().unwrap();
        (xv.grad().unwrap().to_vec(), wv.grad().unwrap().to_vec())
    };
    let (s, p) = serial_vs_parallel(run);
    assert_eq!(s.0, p.0, "net dL/dx");
    assert_eq!(s.1, p.1, "net dL/dW");
}

#[test]
fn migrated_backwards_match_finite_difference_under_parallel_dispatch() {
    let _guard = nt_lock();
    let before = parallel::num_threads();
    parallel::set_num_threads(4);
    let mut rng = Rng::new(16);

    // conv2d: dL/dx and dL/dW through the recorded pullbacks.
    let x = Tensor::randn(&[2, 2, 6, 6], 0.0, 1.0, &mut rng);
    let w = Tensor::randn(&[3, 2, 3, 3], 0.0, 1.0, &mut rng);
    let spec = Conv2dSpec { stride: 1, padding: 1 };
    let wc = Var::from_tensor(w.clone(), false);
    let r = gradcheck(|t| t.conv2d(&wc, spec)?.sum(), &x, 1e-2, 1e-2).unwrap();
    assert!(r.pass, "conv dx: {r:?}");
    let xc = Var::from_tensor(x.clone(), false);
    let r = gradcheck(|t| xc.conv2d(t, spec)?.sum(), &w, 1e-2, 1e-2).unwrap();
    assert!(r.pass, "conv dW: {r:?}");

    // attention: all three inputs…
    let q = Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng);
    let k = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng);
    let v = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng);
    let qc = Var::from_tensor(q.clone(), false);
    let kc = Var::from_tensor(k.clone(), false);
    let vc = Var::from_tensor(v.clone(), false);
    let r = gradcheck(|t| t.attention(&kc, &vc)?.sum(), &q, 1e-2, 1e-2).unwrap();
    assert!(r.pass, "attention dq: {r:?}");
    let r = gradcheck(|t| qc.attention(t, &vc)?.sum(), &k, 1e-2, 1e-2).unwrap();
    assert!(r.pass, "attention dk: {r:?}");
    let r = gradcheck(|t| qc.attention(&kc, t)?.sum(), &v, 1e-2, 1e-2).unwrap();
    assert!(r.pass, "attention dv: {r:?}");

    // …including a non-contiguous (transposed-view) query: the leaf is
    // [d, seq_q] and the graph transposes it before the attention call.
    let qt = Tensor::randn(&[4, 3], 0.0, 1.0, &mut rng);
    let via_view = |t: &Var| t.transpose(0, 1)?.attention(&kc, &vc)?.sum();
    let r = gradcheck(via_view, &qt, 1e-2, 1e-2).unwrap();
    assert!(r.pass, "attention transposed-view dq: {r:?}");

    parallel::set_num_threads(before);
}

#[test]
fn training_is_equivalent_across_thread_counts() {
    let _guard = nt_lock();
    // End-to-end: a short native training run must descend identically in
    // shape (losses combine partials, so compare loosely) at 1 vs 4
    // threads — the whole tape runs through the exec layer.
    use minitensor::coordinator::{Config, TrainConfig, Trainer};
    let cfg = Config::parse(
        "[train]\ndataset = blobs\nn_examples = 256\ninput_side = 2\nhidden = 16\nclasses = 3\nsteps = 40\nbatch_size = 32\nlr = 0.01\noptimizer = adam\n",
    )
    .unwrap();
    let tc = TrainConfig::from_config(&cfg).unwrap();
    let run = |threads: usize| {
        let mut tc = tc.clone();
        tc.threads = threads;
        Trainer::new(tc).run().unwrap()
    };
    let before = parallel::num_threads();
    let r1 = run(1);
    let r4 = run(4);
    parallel::set_num_threads(before);
    assert!(r1.final_loss < r1.initial_loss);
    assert!(r4.final_loss < r4.initial_loss);
    assert!(
        (r1.final_loss - r4.final_loss).abs() < 0.05,
        "{} vs {}",
        r1.final_loss,
        r4.final_loss
    );
}
