//! Chaos tests: arm the `runtime::faults` failpoints and pin the
//! recovery contracts they exist to verify — a compile-path fault
//! surfaces as a structured error and the next eval recompiles cleanly,
//! a forced pool miss degrades to fresh allocation without wrong
//! results, a parallel-chunk panic reaches the submitting thread with
//! its payload intact and leaves the pool reusable, and the serve stack
//! answers **every** request definitively (no hangs) while its workers
//! are being crashed and stalled underneath it.
//!
//! Failpoints are process-global, so every test serializes on
//! [`guard`]. Tests disarm the specific sites they armed (rather than
//! `disarm_all`) so a CI chaos run's `MINITENSOR_FAULTS` background
//! spec — e.g. a low-probability `parallel.chunk` delay — keeps
//! perturbing the rest of the binary.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use minitensor::coordinator::{InferenceServer, NativeModelFactory, ServeConfig};
use minitensor::data::Rng;
use minitensor::error::Error;
use minitensor::nn::{Activation, Dense, Sequential};
use minitensor::runtime::faults::{self, FaultKind};
use minitensor::runtime::parallel;
use minitensor::tensor::{pool, Tensor};

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn mlp_factory(in_features: usize) -> NativeModelFactory {
    NativeModelFactory::new(in_features, move || {
        let mut rng = Rng::new(7);
        Sequential::new()
            .add(Dense::new(in_features, 16, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(16, 4, &mut rng))
    })
}

/// Stringify a caught panic payload (`&'static str` or `String`).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .map(String::from)
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_default()
}

#[test]
fn graph_compile_fault_surfaces_as_error_then_recovers() {
    let _g = guard();
    let a = Tensor::from_vec((0..512).map(|i| i as f32 * 0.01).collect(), &[512]).unwrap();
    let b = Tensor::from_vec(vec![1.5; 512], &[512]).unwrap();
    let expr = || a.lazy().mul(&b.lazy()).unwrap().add_scalar(0.25).tanh();
    let expected = expr().eval_eager().unwrap();

    // Force the cache-miss path, then make the compile fail exactly once.
    minitensor::graph::program_cache_clear();
    faults::arm("graph.compile", FaultKind::Error, 1.0, Some(1));
    match expr().eval() {
        Err(Error::FaultInjected { site }) => assert_eq!(site, "graph.compile"),
        other => panic!("expected FaultInjected, got {other:?}"),
    }
    assert_eq!(faults::injected("graph.compile"), 1);

    // The fault fired before any cache entry existed, so the retry
    // recompiles from scratch and must match the eager reference.
    let fused = expr().eval().unwrap();
    assert_eq!(fused.dims(), expected.dims());
    for (f, e) in fused.to_vec().iter().zip(expected.to_vec().iter()) {
        assert_eq!(f.to_bits(), e.to_bits(), "post-recovery eval diverges");
    }
    assert!(faults::disarm("graph.compile"));
}

#[test]
fn forced_pool_miss_degrades_to_fresh_allocation() {
    let _g = guard();
    // Seed the thread-local pool with a buffer big enough to pool
    // (>= 16 KiB) and verify a recycle works unarmed.
    pool::put(Vec::with_capacity(1 << 13)); // 8192 f32 = 32 KiB
    assert!(pool::pooled_count() >= 1);
    assert!(pool::try_take(1024).is_some(), "unarmed take must recycle");
    pool::put(Vec::with_capacity(1 << 13));

    // Armed: every take is a forced miss — the pooled buffer stays put
    // and the caller falls back to a fresh allocation.
    faults::arm("pool.alloc", FaultKind::Error, 1.0, None);
    let pooled_before = pool::pooled_count();
    assert!(pool::try_take(1024).is_none(), "armed take must force a miss");
    assert_eq!(pool::pooled_count(), pooled_before, "forced miss must not consume");
    assert!(faults::injected("pool.alloc") >= 1);

    // Correctness under sustained forced misses: an eager chain is
    // bit-identical to its unarmed run (the pool only recycles storage).
    let x = Tensor::from_vec((0..4096).map(|i| (i % 17) as f32).collect(), &[4096]).unwrap();
    let run = || {
        let mut t = x.add_scalar(1.0);
        for _ in 0..8 {
            t = t.mul_scalar(0.5).add(&x).unwrap();
        }
        t
    };
    let degraded: Vec<u32> = run().to_vec().iter().map(|v| v.to_bits()).collect();
    assert!(faults::disarm("pool.alloc"));
    let normal: Vec<u32> = run().to_vec().iter().map(|v| v.to_bits()).collect();
    assert_eq!(degraded, normal, "forced pool misses changed results");

    // Disarmed: recycling resumes.
    assert!(pool::try_take(1024).is_some(), "disarmed take must recycle again");
}

#[test]
fn parallel_chunk_panic_reaches_the_caller_and_the_pool_stays_usable() {
    let _g = guard();
    faults::arm("parallel.chunk", FaultKind::Panic, 1.0, Some(1));
    let result = std::panic::catch_unwind(|| {
        parallel::parallel_for(10_000, 64, &|_s, _e| {});
    });
    let payload = result.expect_err("injected chunk panic must propagate");
    let msg = panic_msg(payload.as_ref());
    assert!(msg.contains("injected fault at parallel.chunk"), "{msg}");
    assert_eq!(faults::injected("parallel.chunk"), 1);
    assert!(faults::disarm("parallel.chunk"));

    // The pool must be fully reusable after the contained panic: every
    // index is visited exactly once by the next dispatch.
    let total = AtomicU64::new(0);
    parallel::parallel_for(10_000, 64, &|s, e| {
        total.fetch_add((e - s) as u64, Ordering::Relaxed);
    });
    assert_eq!(total.load(Ordering::Relaxed), 10_000);
}

/// The ISSUE's acceptance scenario: a closed-loop load with
/// `serve.worker.forward` armed to panic at probability 0.2. Every
/// request must get a *definite* reply (Ok or a structured error —
/// the joins below hang the test otherwise), the server must recover
/// every crashed replica, and the blast radius must be visible on the
/// restart/fault counters and `/healthz`.
#[test]
fn closed_loop_load_under_forward_panics_gets_definite_replies_and_recovers() {
    let _g = guard();
    let cfg = ServeConfig::new()
        .workers(2)
        .max_batch(1)
        .max_wait_ms(0)
        .queue_depth(64)
        .restart_backoff_ms(1)
        .metrics_port(0)
        .build()
        .unwrap();
    let server = Arc::new(InferenceServer::start(mlp_factory(4), cfg).unwrap());
    let addr = server.metrics_addr().expect("metrics endpoint running");

    faults::arm("serve.worker.forward", FaultKind::Panic, 0.2, None);
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let s = server.clone();
            std::thread::spawn(move || {
                let mut ok = 0u64;
                let mut crashed = 0u64;
                for i in 0..15 {
                    match s.infer(vec![t as f32, i as f32, 0.5, -0.5]) {
                        Ok(out) => {
                            assert_eq!(out.len(), 4);
                            ok += 1;
                        }
                        Err(Error::WorkerCrashed { detail, .. }) => {
                            assert!(detail.contains("injected fault"), "{detail}");
                            crashed += 1;
                        }
                        Err(e) => panic!("indefinite/unexpected reply: {e}"),
                    }
                }
                (ok, crashed)
            })
        })
        .collect();
    let (mut ok, mut crashed) = (0u64, 0u64);
    for h in handles {
        let (o, c) = h.join().unwrap();
        ok += o;
        crashed += c;
    }
    faults::disarm("serve.worker.forward");
    assert_eq!(ok + crashed, 60, "every request answered exactly once");
    assert!(ok >= 1, "some requests must succeed under p=0.2");
    assert!(crashed >= 1, "p=0.2 over 60 forwards must inject");

    // Recovery: every crash is followed by an in-place replica rebuild.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.stats().worker_restarts < server.stats().worker_crashes
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = server.stats();
    assert_eq!(stats.worker_crashes, crashed);
    assert_eq!(stats.worker_restarts, stats.worker_crashes, "{stats:?}");
    assert_eq!(stats.health, "live");
    assert_eq!(stats.workers_alive, 2);
    assert!(server.infer(vec![0.0; 4]).is_ok(), "recovered server serves");

    // The blast radius is on the wire: /healthz reports live plus the
    // restart counter, /metrics carries the injection total.
    let (head, body) = http_get(addr, "/healthz");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(body.contains("\"status\":\"live\""), "{body}");
    let (_, metrics_body) = http_get(addr, "/metrics");
    assert!(
        sample(&metrics_body, "minitensor_serve_worker_restarts_total") >= crashed as f64,
        "restart counter missing from scrape"
    );
    assert!(
        sample(&metrics_body, "minitensor_faults_injected_total") >= crashed as f64,
        "fault counter missing from scrape"
    );

    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
}

#[test]
fn delay_fault_trips_the_stuck_worker_watchdog() {
    let _g = guard();
    let cfg = ServeConfig::new()
        .workers(1)
        .max_batch(1)
        .max_wait_ms(0)
        .worker_timeout_ms(50)
        .restart_backoff_ms(1)
        .build()
        .unwrap();
    let server = InferenceServer::start(mlp_factory(4), cfg).unwrap();

    // Exactly one forward stalls for 400 ms — far past the 50 ms
    // watchdog timeout. The client must get its reply from the watchdog
    // (replica abandoned), not wait out the stall.
    faults::arm("serve.worker.forward", FaultKind::DelayMs(400), 1.0, Some(1));
    let t0 = Instant::now();
    match server.infer(vec![0.0; 4]) {
        Err(Error::WorkerCrashed { detail, .. }) => {
            assert!(detail.contains("worker timeout"), "{detail}");
        }
        other => panic!("expected the watchdog's WorkerCrashed, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_millis(350),
        "reply must arrive from the watchdog, not after the stall: {:?}",
        t0.elapsed()
    );
    faults::disarm("serve.worker.forward");

    let deadline = Instant::now() + Duration::from_secs(10);
    while server.stats().worker_timeouts < 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(server.stats().worker_timeouts, 1);

    // The supervisor replaced the abandoned replica; service resumes.
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut recovered = false;
    while Instant::now() < deadline {
        if server.infer(vec![0.5; 4]).is_ok() {
            recovered = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(recovered, "replacement replica never came up");
    // The abandoned thread is still sleeping inside its 400 ms stall;
    // shutdown must not block on it (it is detached, discards its stale
    // result on wake, and exits).
    server.shutdown();
}

#[test]
fn drain_and_shutdown_join_cleanly_with_faults_armed() {
    let _g = guard();
    faults::arm("serve.worker.forward", FaultKind::Panic, 0.15, None);
    faults::arm("parallel.chunk", FaultKind::DelayMs(1), 0.05, None);
    let cfg = ServeConfig::new()
        .workers(2)
        .max_batch(4)
        .max_wait_ms(1)
        .restart_backoff_ms(1)
        .build()
        .unwrap();
    let server = Arc::new(InferenceServer::start(mlp_factory(4), cfg).unwrap());
    let handles: Vec<_> = (0..12)
        .map(|i| {
            let s = server.clone();
            std::thread::spawn(move || s.infer(vec![i as f32, 0.0, 0.0, 0.0]))
        })
        .collect();
    for h in handles {
        // Definite replies only — Ok or WorkerCrashed, never a hang.
        match h.join().unwrap() {
            Ok(out) => assert_eq!(out.len(), 4),
            Err(Error::WorkerCrashed { .. }) => {}
            Err(e) => panic!("unexpected reply under chaos: {e}"),
        }
    }
    server.drain();
    assert!(server.infer(vec![0.0; 4]).is_err(), "drained server must refuse");
    let Ok(server) = Arc::try_unwrap(server) else {
        panic!("all clients joined; no other Arc holders remain");
    };
    // The real assertion: shutdown joins every thread with faults still
    // armed (a worker mid-crash or mid-rebuild must not wedge it).
    server.shutdown();
    faults::disarm("serve.worker.forward");
    faults::disarm("parallel.chunk");
}

/// Blocking HTTP GET against the metrics endpoint; returns (head, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect to metrics endpoint");
    stream
        .write_all(
            format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
                .as_bytes(),
        )
        .unwrap();
    let mut resp = Vec::new();
    stream.read_to_end(&mut resp).unwrap();
    let text = String::from_utf8(resp).expect("UTF-8 response");
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body separator");
    (head.to_string(), body.to_string())
}

/// First sample value for `name` in a Prometheus text body; 0 if absent.
fn sample(body: &str, name: &str) -> f64 {
    body.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| {
            let (n, v) = l.rsplit_once(' ')?;
            if n == name {
                v.parse().ok()
            } else {
                None
            }
        })
        .unwrap_or(0.0)
}
