//! Integration tests for the lazy expression-graph subsystem: fused
//! evaluation through the public API, bitwise parity with eager chains
//! across explicit thread counts, dispatch/allocation accounting, and
//! differentiability of fused forwards.

use std::sync::{Mutex, MutexGuard, OnceLock};

use minitensor::autograd::{gradcheck, Var};
use minitensor::data::Rng;
use minitensor::runtime::{parallel, stats};
use minitensor::tensor::Tensor;

/// The thread count is process-global: tests that flip it serialize here.
fn nt_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.to_vec().into_iter().map(f32::to_bits).collect()
}

#[test]
fn six_op_chain_bitwise_identical_across_thread_counts() {
    let _guard = nt_lock();
    let before = parallel::num_threads();
    let mut rng = Rng::new(21);
    let a = Tensor::randn(&[100_000], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[100_000], 0.0, 1.0, &mut rng);
    let run_fused = || {
        let (la, lb) = (a.lazy(), b.lazy());
        la.mul(&lb)
            .unwrap()
            .add(&la)
            .unwrap()
            .relu()
            .mul(&lb)
            .unwrap()
            .sub(&la)
            .unwrap()
            .relu()
            .eval()
            .unwrap()
    };
    let run_eager = || {
        a.mul(&b)
            .unwrap()
            .add(&a)
            .unwrap()
            .relu()
            .mul(&b)
            .unwrap()
            .sub(&a)
            .unwrap()
            .relu()
    };
    let mut reference: Option<Vec<u32>> = None;
    for threads in [1usize, 2, 4] {
        parallel::set_num_threads(threads);
        let f = bits(&run_fused());
        assert_eq!(f, bits(&run_eager()), "fused vs eager at {threads} threads");
        match &reference {
            None => reference = Some(f),
            Some(r) => assert_eq!(&f, r, "thread-count invariance at {threads}"),
        }
    }
    parallel::set_num_threads(before);
}

#[test]
fn shared_subexpression_costs_one_extra_dispatch() {
    // y = tanh(a) * tanh(a) (same node reused): two fused kernels —
    // one materializing tanh(a), one for the product — never three.
    let a = Tensor::arange(0.0, 512.0).mul_scalar(0.01);
    let c = a.lazy().tanh();
    let expr = c.mul(&c).unwrap();
    assert_eq!(expr.region_count(), 2);
    let before = stats::snapshot();
    let y = expr.eval().unwrap();
    let d = stats::snapshot().delta(&before);
    assert_eq!(d.exec_dispatches, 2);
    assert_eq!(d.output_allocs, 2);
    let want = a.tanh();
    let want = want.mul(&want).unwrap();
    assert_eq!(bits(&y), bits(&want));
}

#[test]
fn fused_epilogue_and_eager_reduction_agree_at_scale() {
    let _guard = nt_lock();
    let before = parallel::num_threads();
    let mut rng = Rng::new(22);
    // Straddles several REDUCE_CHUNK boundaries.
    let a = Tensor::randn(&[200_000], 0.0, 1.0, &mut rng);
    for threads in [1usize, 2, 4] {
        parallel::set_num_threads(threads);
        for reduce in ["sum", "mean", "max", "min"] {
            let l = a.lazy().square().add_scalar(-0.5);
            let fused = match reduce {
                "sum" => l.sum(),
                "mean" => l.mean(),
                "max" => l.max_all(),
                _ => l.min_all(),
            }
            .eval()
            .unwrap()
            .item()
            .unwrap();
            let m = a.square().add_scalar(-0.5);
            let eager = match reduce {
                "sum" => m.sum(),
                "mean" => m.mean(),
                "max" => m.max_all(),
                _ => m.min_all(),
            }
            .item()
            .unwrap();
            assert_eq!(
                fused.to_bits(),
                eager.to_bits(),
                "{reduce} at {threads} threads"
            );
        }
    }
    parallel::set_num_threads(before);
}

#[test]
fn var_fused_composite_passes_gradcheck() {
    let mut rng = Rng::new(23);
    let x0 = Tensor::randn(&[3, 4], 0.0, 0.6, &mut rng);
    let w = Var::from_tensor(Tensor::randn(&[4], 0.0, 0.6, &mut rng), false);
    let report = gradcheck(
        |x: &Var| {
            Var::fused(&[x, &w], |l| {
                Ok(l[0].mul(&l[1])?.sigmoid().add(&l[0].gelu())?.mean())
            })
        },
        &x0,
        1e-3,
        2e-2,
    )
    .unwrap();
    assert!(report.pass, "{report:?}");
}

#[test]
fn var_fused_inside_larger_tape_composes() {
    // A fused region feeding an eager matmul: gradients flow through both.
    let mut rng = Rng::new(24);
    let a = Var::from_tensor(Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng), true);
    let b = Var::from_tensor(Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng), true);
    let w = Var::from_tensor(Tensor::randn(&[4, 2], 0.0, 1.0, &mut rng), true);
    let h = Var::fused(&[&a, &b], |l| l[0].mul(&l[1])?.relu().add(&l[0])).unwrap();
    assert_eq!(h.dims(), vec![3, 4]);
    let loss = h.matmul(&w).unwrap().square().sum().unwrap();
    loss.backward().unwrap();
    assert_eq!(a.grad().unwrap().dims(), &[3, 4]);
    assert_eq!(b.grad().unwrap().dims(), &[3, 4]);
    assert_eq!(w.grad().unwrap().dims(), &[4, 2]);
}

#[test]
fn lazy_handles_are_reusable_and_observable() {
    let a = Tensor::arange(0.0, 16.0);
    let expr = a.lazy().relu().add_scalar(1.0).sum();
    // eval twice: same value, no hidden state.
    let v1 = expr.eval().unwrap().item().unwrap();
    let v2 = expr.eval().unwrap().item().unwrap();
    assert_eq!(v1.to_bits(), v2.to_bits());
    assert_eq!(expr.node_count(), 4);
    assert_eq!(expr.dims(), &[] as &[usize]);
}
