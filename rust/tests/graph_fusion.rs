//! Integration tests for the lazy expression-graph subsystem: fused
//! evaluation through the public API, bitwise parity with eager chains
//! across explicit thread counts, dispatch/allocation accounting, and
//! differentiability of fused forwards.

use std::sync::{Mutex, MutexGuard, OnceLock};

use minitensor::autograd::{gradcheck, Var};
use minitensor::data::Rng;
use minitensor::runtime::{parallel, stats};
use minitensor::tensor::Tensor;

/// The thread count is process-global: tests that flip it serialize here.
fn nt_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.to_vec().into_iter().map(f32::to_bits).collect()
}

#[test]
fn six_op_chain_bitwise_identical_across_thread_counts() {
    let _guard = nt_lock();
    let before = parallel::num_threads();
    let mut rng = Rng::new(21);
    let a = Tensor::randn(&[100_000], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[100_000], 0.0, 1.0, &mut rng);
    let run_fused = || {
        let (la, lb) = (a.lazy(), b.lazy());
        la.mul(&lb)
            .unwrap()
            .add(&la)
            .unwrap()
            .relu()
            .mul(&lb)
            .unwrap()
            .sub(&la)
            .unwrap()
            .relu()
            .eval()
            .unwrap()
    };
    let run_eager = || {
        a.mul(&b)
            .unwrap()
            .add(&a)
            .unwrap()
            .relu()
            .mul(&b)
            .unwrap()
            .sub(&a)
            .unwrap()
            .relu()
    };
    let mut reference: Option<Vec<u32>> = None;
    for threads in [1usize, 2, 4] {
        parallel::set_num_threads(threads);
        let f = bits(&run_fused());
        assert_eq!(f, bits(&run_eager()), "fused vs eager at {threads} threads");
        match &reference {
            None => reference = Some(f),
            Some(r) => assert_eq!(&f, r, "thread-count invariance at {threads}"),
        }
    }
    parallel::set_num_threads(before);
}

#[test]
fn shared_subexpression_costs_one_extra_dispatch() {
    // y = tanh(a) * tanh(a) (same node reused): two fused kernels —
    // one materializing tanh(a), one for the product — never three.
    let a = Tensor::arange(0.0, 512.0).mul_scalar(0.01);
    let c = a.lazy().tanh();
    let expr = c.mul(&c).unwrap();
    assert_eq!(expr.region_count(), 2);
    let before = stats::snapshot();
    let y = expr.eval().unwrap();
    let d = stats::snapshot().delta(&before);
    assert_eq!(d.exec_dispatches, 2);
    assert_eq!(d.output_allocs, 2);
    let want = a.tanh();
    let want = want.mul(&want).unwrap();
    assert_eq!(bits(&y), bits(&want));
}

#[test]
fn fused_epilogue_and_eager_reduction_agree_at_scale() {
    let _guard = nt_lock();
    let before = parallel::num_threads();
    let mut rng = Rng::new(22);
    // Straddles several REDUCE_CHUNK boundaries.
    let a = Tensor::randn(&[200_000], 0.0, 1.0, &mut rng);
    for threads in [1usize, 2, 4] {
        parallel::set_num_threads(threads);
        for reduce in ["sum", "mean", "max", "min"] {
            let l = a.lazy().square().add_scalar(-0.5);
            let fused = match reduce {
                "sum" => l.sum(),
                "mean" => l.mean(),
                "max" => l.max_all(),
                _ => l.min_all(),
            }
            .eval()
            .unwrap()
            .item()
            .unwrap();
            let m = a.square().add_scalar(-0.5);
            let eager = match reduce {
                "sum" => m.sum(),
                "mean" => m.mean(),
                "max" => m.max_all(),
                _ => m.min_all(),
            }
            .item()
            .unwrap();
            assert_eq!(
                fused.to_bits(),
                eager.to_bits(),
                "{reduce} at {threads} threads"
            );
        }
    }
    parallel::set_num_threads(before);
}

#[test]
fn var_fused_composite_passes_gradcheck() {
    let mut rng = Rng::new(23);
    let x0 = Tensor::randn(&[3, 4], 0.0, 0.6, &mut rng);
    let w = Var::from_tensor(Tensor::randn(&[4], 0.0, 0.6, &mut rng), false);
    let report = gradcheck(
        |x: &Var| {
            Var::fused(&[x, &w], |l| {
                Ok(l[0].mul(&l[1])?.sigmoid().add(&l[0].gelu())?.mean())
            })
        },
        &x0,
        1e-3,
        2e-2,
    )
    .unwrap();
    assert!(report.pass, "{report:?}");
}

#[test]
fn var_fused_inside_larger_tape_composes() {
    // A fused region feeding an eager matmul: gradients flow through both.
    let mut rng = Rng::new(24);
    let a = Var::from_tensor(Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng), true);
    let b = Var::from_tensor(Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng), true);
    let w = Var::from_tensor(Tensor::randn(&[4, 2], 0.0, 1.0, &mut rng), true);
    let h = Var::fused(&[&a, &b], |l| l[0].mul(&l[1])?.relu().add(&l[0])).unwrap();
    assert_eq!(h.dims(), vec![3, 4]);
    let loss = h.matmul(&w).unwrap().square().sum().unwrap();
    loss.backward().unwrap();
    assert_eq!(a.grad().unwrap().dims(), &[3, 4]);
    assert_eq!(b.grad().unwrap().dims(), &[3, 4]);
    assert_eq!(w.grad().unwrap().dims(), &[4, 2]);
}

#[test]
fn second_eval_of_same_graph_is_a_cache_hit_with_zero_tape_builds() {
    // The program cache memoizes compiled plans by DAG structure: the
    // second eval of a structurally identical graph — even one rebuilt
    // from scratch — must be a pure cache hit (zero new tape builds)
    // with the same single dispatch and bit-identical output.
    let a = Tensor::arange(-64.0, 64.0);
    let b = Tensor::arange(0.0, 128.0);
    let build = || {
        a.lazy()
            .mul(&b.lazy())
            .unwrap()
            .add(&a.lazy())
            .unwrap()
            .relu()
    };
    minitensor::graph::program_cache_clear();
    let before = stats::snapshot();
    let y1 = build().eval().unwrap();
    let d1 = stats::snapshot().delta(&before);
    assert_eq!(d1.program_cache_misses, 1, "cold eval compiles once");
    assert_eq!(d1.program_cache_hits, 0);
    assert_eq!(d1.exec_dispatches, 1);

    let before = stats::snapshot();
    let y2 = build().eval().unwrap();
    let d2 = stats::snapshot().delta(&before);
    assert_eq!(d2.program_cache_hits, 1, "second eval hits the cache");
    assert_eq!(d2.program_cache_misses, 0, "zero new tape builds");
    assert_eq!(d2.exec_dispatches, 1, "cached plan is still one dispatch");
    assert_eq!(d2.output_allocs, 1);
    assert_eq!(bits(&y1), bits(&y2));
}

#[test]
fn fused_softmax_bitwise_equals_unfused_pair_across_threads() {
    // The scaled softmax row kernel (used inside attention) vs the
    // unfused mul_scalar + softmax chain: bit-identical at 1 and 4
    // threads, and one dispatch instead of two.
    let _guard = nt_lock();
    let before_threads = parallel::num_threads();
    let mut rng = Rng::new(25);
    let t = Tensor::randn(&[64, 96], 0.0, 2.0, &mut rng);
    let scale = 1.0 / 96f32.sqrt();
    let mut reference: Option<Vec<u32>> = None;
    for threads in [1usize, 4] {
        parallel::set_num_threads(threads);
        let before = stats::snapshot();
        let fused = minitensor::ops::softmax::softmax_scaled_lastdim(&t, scale).unwrap();
        let d = stats::snapshot().delta(&before);
        assert_eq!(d.exec_dispatches, 1, "one dispatch at {threads} threads");
        assert_eq!(d.output_allocs, 1);
        let eager = t.mul_scalar(scale).softmax().unwrap();
        assert_eq!(bits(&fused), bits(&eager), "parity at {threads} threads");
        match &reference {
            None => reference = Some(bits(&fused)),
            Some(r) => assert_eq!(&bits(&fused), r, "thread invariance"),
        }
    }
    parallel::set_num_threads(before_threads);
}

#[test]
fn mlp_forward_fuses_by_default_with_fewer_dispatches_and_allocs() {
    // Linear→ReLU→Linear→softmax: the fused-by-default nn:: forward must
    // execute with strictly fewer dispatches and output allocations than
    // the eager count, produce bitwise-identical outputs and gradients
    // at 1 and 4 threads, and never trip a fusion bailout.
    use minitensor::nn::{Activation, Dense, Module, Sequential};
    let _guard = nt_lock();
    let before_threads = parallel::num_threads();
    let mut rng = Rng::new(26);
    let model = Sequential::new()
        .add(Dense::new(16, 32, &mut rng))
        .add(Activation::Relu)
        .add(Dense::new(32, 10, &mut rng));
    let x = Var::from_tensor(Tensor::randn(&[8, 16], 0.0, 1.0, &mut rng), false);

    let run = |fuse: bool| {
        minitensor::graph::set_nn_fusion_enabled(fuse);
        model.zero_grad();
        let before = stats::snapshot();
        let y = model.forward(&x, false).unwrap().softmax().unwrap();
        let d = stats::snapshot().delta(&before);
        y.square().sum().unwrap().backward().unwrap();
        let grads: Vec<Vec<u32>> = model
            .parameters()
            .iter()
            .map(|p| bits(&p.grad().unwrap()))
            .collect();
        (d, bits(&y.data()), grads)
    };

    let initial = minitensor::graph::nn_fusion_enabled();
    for threads in [1usize, 4] {
        parallel::set_num_threads(threads);
        let (df, yf, gf) = run(true);
        let (de, ye, ge) = run(false);
        assert!(
            df.exec_dispatches < de.exec_dispatches,
            "fused must dispatch strictly less: {} vs {} (threads={threads})",
            df.exec_dispatches,
            de.exec_dispatches
        );
        assert!(
            df.output_allocs < de.output_allocs,
            "fused must allocate strictly less: {} vs {} (threads={threads})",
            df.output_allocs,
            de.output_allocs
        );
        assert_eq!(df.fusion_bailouts, 0, "MLP forward must not bail out");
        assert_eq!(yf, ye, "fused output == eager output (threads={threads})");
        assert_eq!(gf, ge, "fused grads == eager grads (threads={threads})");
    }
    minitensor::graph::set_nn_fusion_enabled(initial);
    parallel::set_num_threads(before_threads);
}

#[test]
fn fusion_bailout_counter_tracks_degraded_regions() {
    // A wider-than-MAX_FUSED_INPUTS tree must still evaluate correctly
    // and must account for the degradation in the stats — including on
    // cache-hit re-evals, which still dispatch the degraded plan.
    minitensor::graph::program_cache_clear();
    let leaves: Vec<Tensor> = (0..20)
        .map(|i| Tensor::full(&[8], i as f32 + 0.5))
        .collect();
    let build = || {
        let mut acc = leaves[0].lazy();
        for l in &leaves[1..] {
            acc = acc.add(&l.lazy()).unwrap();
        }
        acc
    };
    let before = stats::snapshot();
    let y = build().eval().unwrap();
    let cold = stats::snapshot().delta(&before);
    assert!(cold.fusion_bailouts > 0, "wide tree must record its bailouts");
    let before = stats::snapshot();
    let y2 = build().eval().unwrap();
    let warm = stats::snapshot().delta(&before);
    assert_eq!(warm.program_cache_hits, 1);
    assert_eq!(
        warm.fusion_bailouts, cold.fusion_bailouts,
        "cached degraded plans keep counting per eval"
    );
    let mut want = leaves[0].clone();
    for l in &leaves[1..] {
        want = want.add(l).unwrap();
    }
    assert_eq!(bits(&y), bits(&want));
    assert_eq!(bits(&y2), bits(&want));
}

#[test]
fn lazy_handles_are_reusable_and_observable() {
    let a = Tensor::arange(0.0, 16.0);
    let expr = a.lazy().relu().add_scalar(1.0).sum();
    // eval twice: same value, no hidden state.
    let v1 = expr.eval().unwrap().item().unwrap();
    let v2 = expr.eval().unwrap().item().unwrap();
    assert_eq!(v1.to_bits(), v2.to_bits());
    assert_eq!(expr.node_count(), 4);
    assert_eq!(expr.dims(), &[] as &[usize]);
}
