//! Cross-module integration tests: end-to-end training, serving, layer
//! composition, and engine-vs-baseline agreement — the paper's §5
//! "end-to-end examples that train small models and confirm consistent
//! loss descent".

use minitensor::autograd::{gradcheck, Var};
use minitensor::baselines::NaiveTensor;
use minitensor::coordinator::{
    Config, InferenceServer, NativeModelFactory, ServeConfig, TrainConfig, Trainer,
};
use minitensor::data::{self, DataLoader, Rng};
use minitensor::nn::{losses, Activation, BatchNorm1d, Conv2d, Dense, Dropout, Module, Sequential};
use minitensor::optim::{Adam, Optimizer, Sgd};
use minitensor::tensor::Tensor;

#[test]
fn train_mlp_on_blobs_reaches_high_accuracy() {
    let cfg = Config::parse(
        "[train]\ndataset = blobs\nn_examples = 512\ninput_side = 2\nhidden = 32\nclasses = 4\nsteps = 150\nbatch_size = 64\nlr = 0.005\noptimizer = adam\n",
    )
    .unwrap();
    let tc = TrainConfig::from_config(&cfg).unwrap();
    let report = Trainer::new(tc).run().unwrap();
    assert!(report.descended(1.5), "{report:?}");
    assert!(report.accuracy.unwrap() > 0.9, "{report:?}");
}

#[test]
fn train_spiral_with_sgd_momentum() {
    let cfg = Config::parse(
        "[train]\ndataset = spiral\nn_examples = 300\nclasses = 3\nhidden = 32,16\nsteps = 200\nbatch_size = 50\nlr = 0.05\noptimizer = sgd\nmomentum = 0.9\n",
    )
    .unwrap();
    let tc = TrainConfig::from_config(&cfg).unwrap();
    let report = Trainer::new(tc).run().unwrap();
    assert!(
        report.final_loss < report.initial_loss,
        "spiral loss should descend: {report:?}"
    );
}

#[test]
fn regression_with_mse_converges_to_ground_truth() {
    // y = x·w* + b*; a linear model must recover it almost exactly.
    let ds = data::regression_linear(512, 4, 0.01, 3);
    let mut rng = Rng::new(4);
    let layer = Dense::new(4, 1, &mut rng);
    let mut opt = Adam::new(layer.parameters(), 0.05);
    let mut loader = DataLoader::new(ds.clone(), 64, true, 1);
    let mut final_loss = f32::INFINITY;
    for _ in 0..300 {
        let Some(batch) = loader.next() else {
            loader.reset();
            continue;
        };
        let x = Var::from_tensor(batch.x, false);
        let pred = layer.forward(&x, true).unwrap();
        let loss = losses::mse(&pred, &batch.y).unwrap();
        final_loss = loss.item().unwrap();
        opt.zero_grad();
        loss.backward().unwrap();
        opt.step().unwrap();
    }
    assert!(final_loss < 0.01, "final mse {final_loss}");
}

#[test]
fn cnn_stack_trains_on_synthetic_images() {
    // Tiny conv net on 8×8 synthetic digits: conv→relu→pool→dense.
    let mut rng = Rng::new(5);
    let ds = data::synthetic_mnist(128, 8, 6);
    let conv = Conv2d::new(1, 4, 3, 1, 1, &mut rng);
    let head = Dense::new(4 * 4 * 4, 10, &mut rng);

    let mut params = conv.parameters();
    params.extend(head.parameters());
    let mut opt = Adam::new(params, 2e-3);

    let mut losses_log = Vec::new();
    let mut loader = DataLoader::new(ds, 32, true, 7).drop_last();
    for _ in 0..40 {
        let Some(batch) = loader.next() else {
            loader.reset();
            continue;
        };
        let b = batch.x.dims()[0];
        let img = Var::from_tensor(batch.x.reshape(&[b, 1, 8, 8]).unwrap(), false);
        let c = conv.forward(&img, true).unwrap().relu();
        let p = c.max_pool2d(2).unwrap(); // [b,4,4,4]
        let flat = p.reshape(&[b, 4 * 4 * 4]).unwrap();
        let logits = head.forward(&flat, true).unwrap();
        let loss = losses::cross_entropy(&logits, &batch.y).unwrap();
        losses_log.push(loss.item().unwrap());
        opt.zero_grad();
        loss.backward().unwrap();
        opt.step().unwrap();
    }
    let first = losses_log[0];
    let last = *losses_log.last().unwrap();
    assert!(last < first, "cnn loss descend: {first} -> {last}");
}

#[test]
fn deep_stack_with_batchnorm_dropout_trains() {
    let mut rng = Rng::new(8);
    let model = Sequential::new()
        .add(Dense::new(2, 32, &mut rng))
        .add(BatchNorm1d::new(32))
        .add(Activation::Relu)
        .add(Dropout::new(0.2, 9))
        .add(Dense::new(32, 2, &mut rng));
    let ds = data::two_moons(256, 0.1, 10);
    let mut opt = Adam::new(model.parameters(), 5e-3);
    let mut loader = DataLoader::new(ds.clone(), 64, true, 11).drop_last();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..100 {
        let Some(batch) = loader.next() else {
            loader.reset();
            continue;
        };
        let x = Var::from_tensor(batch.x, false);
        let logits = model.forward(&x, true).unwrap();
        let loss = losses::cross_entropy(&logits, &batch.y).unwrap();
        last = loss.item().unwrap();
        first.get_or_insert(last);
        opt.zero_grad();
        loss.backward().unwrap();
        opt.step().unwrap();
    }
    assert!(last < first.unwrap(), "{:?} -> {last}", first);

    // Eval mode must be deterministic (dropout off, running stats).
    let x = Var::from_tensor(ds.x.narrow(0, 0, 8).unwrap().contiguous(), false);
    let a = model.forward(&x, false).unwrap().data().to_vec();
    let b = model.forward(&x, false).unwrap().data().to_vec();
    assert_eq!(a, b);
}

#[test]
fn whole_model_gradcheck() {
    // Finite differences through a 2-layer MLP + CE loss (eq 11 at system
    // level, not just per-op).
    let mut rng = Rng::new(12);
    let model = Sequential::new()
        .add(Dense::new(3, 8, &mut rng))
        .add(Activation::Tanh)
        .add(Dense::new(8, 3, &mut rng));
    let labels = Tensor::from_vec_i32(vec![0, 2, 1, 0], &[4]).unwrap();
    let x0 = Tensor::randn(&[4, 3], 0.0, 1.0, &mut rng);
    let report = gradcheck(
        |v| {
            let logits = model.forward(v, true)?;
            losses::cross_entropy(&logits, &labels)
        },
        &x0,
        1e-3,
        1e-2,
    )
    .unwrap();
    assert!(report.pass, "{report:?}");
}

#[test]
fn serving_trained_model_end_to_end() {
    // Train on moons, then serve and check classification through the
    // batching server matches direct inference.
    let cfg = Config::parse(
        "[train]\ndataset = moons\nn_examples = 256\nclasses = 2\nhidden = 16\nsteps = 150\nbatch_size = 64\nlr = 0.01\noptimizer = adam\n",
    )
    .unwrap();
    let tc = TrainConfig::from_config(&cfg).unwrap();
    let trainer = Trainer::new(tc);
    let ds = trainer.dataset().unwrap();
    let model = trainer.build_model(2, 2);
    // quick manual training so we keep the model afterwards
    let mut opt = Adam::new(model.parameters(), 0.01);
    let mut loader = DataLoader::new(ds.clone(), 64, true, 1).drop_last();
    for _ in 0..150 {
        let Some(batch) = loader.next() else {
            loader.reset();
            continue;
        };
        let x = Var::from_tensor(batch.x, false);
        let loss =
            losses::cross_entropy(&model.forward(&x, true).unwrap(), &batch.y).unwrap();
        opt.zero_grad();
        loss.backward().unwrap();
        opt.step().unwrap();
    }

    let factory = NativeModelFactory::from_trained(&model, 2, move || trainer.build_model(2, 2));
    let server = InferenceServer::start(factory, ServeConfig::default()).unwrap();
    let mut correct = 0;
    let n = 64;
    for i in 0..n {
        let feats = ds.x.row(i).unwrap().to_vec();
        let label = ds.y.at(&[i]).unwrap() as usize;
        let logits = server.infer(feats).unwrap();
        let pred = if logits[1] > logits[0] { 1 } else { 0 };
        if pred == label {
            correct += 1;
        }
    }
    assert!(correct >= 55, "served accuracy {correct}/{n}");
    let stats = server.stats();
    assert_eq!(stats.requests, n as u64);
    server.shutdown();
}

#[test]
fn engine_and_naive_baseline_agree_on_mlp_forward() {
    // The C2 baseline must be numerically equivalent, just slow.
    let mut rng = Rng::new(13);
    let x = Tensor::randn(&[4, 6], 0.0, 1.0, &mut rng);
    let w = Tensor::randn(&[6, 3], 0.0, 1.0, &mut rng);
    let engine_out = x.matmul(&w).unwrap().relu();

    let nx = NaiveTensor::from_vec(&x.to_vec(), &[4, 6]);
    let nw = NaiveTensor::from_vec(&w.to_vec(), &[6, 3]);
    let naive_out = nx.matmul(&nw).relu();
    for (a, b) in engine_out.to_vec().iter().zip(naive_out.values()) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn optimizer_comparison_all_converge_on_same_problem() {
    // eq 9 vs eq 10 vs RMSprop on the same quadratic bowl.
    for name in ["sgd", "adam", "rmsprop"] {
        let p = Var::from_tensor(
            Tensor::from_vec(vec![2.0, -1.5, 0.5], &[3]).unwrap(),
            true,
        );
        let mut opt: Box<dyn Optimizer> = match name {
            "sgd" => Box::new(Sgd::with_momentum(vec![p.clone()], 0.1, 0.9, 0.0)),
            "adam" => Box::new(Adam::new(vec![p.clone()], 0.1)),
            _ => Box::new(minitensor::optim::RmsProp::new(vec![p.clone()], 0.05, 0.9)),
        };
        for _ in 0..200 {
            opt.zero_grad();
            p.square().sum().unwrap().backward().unwrap();
            opt.step().unwrap();
        }
        let norm: f32 = p.data().to_vec().iter().map(|v| v * v).sum();
        assert!(norm < 1e-2, "{name} failed to converge: {norm}");
    }
}

#[test]
fn train_save_load_serve_workflow() {
    // The full downstream-user loop: train → checkpoint → fresh model →
    // load → serve; the served outputs must match the trained model.
    let mut rng = Rng::new(21);
    let ds = data::gaussian_blobs(256, 4, 3, 0.4, 22);
    let build = |rng: &mut Rng| {
        Sequential::new()
            .add(Dense::new(4, 16, rng))
            .add(Activation::Relu)
            .add(Dense::new(16, 3, rng))
    };
    let model = build(&mut rng);
    let mut opt = Adam::new(model.parameters(), 0.01);
    let mut loader = DataLoader::new(ds.clone(), 64, true, 23).drop_last();
    for _ in 0..80 {
        let Some(batch) = loader.next() else {
            loader.reset();
            continue;
        };
        let x = Var::from_tensor(batch.x, false);
        let loss =
            losses::cross_entropy(&model.forward(&x, true).unwrap(), &batch.y).unwrap();
        opt.zero_grad();
        loss.backward().unwrap();
        opt.step().unwrap();
    }
    let path = std::env::temp_dir().join(format!("mt_ckpt_{}", std::process::id()));
    minitensor::nn::save_parameters(&model.parameters(), &path).unwrap();

    // Fresh model, different init; load the checkpoint, then serve it.
    let model2 = build(&mut rng);
    minitensor::nn::load_parameters(&model2.parameters(), &path).unwrap();
    let expect = model
        .forward(&Var::from_tensor(ds.x.row(0).unwrap().reshape(&[1, 4]).unwrap(), false), false)
        .unwrap()
        .data()
        .to_vec();
    let factory =
        NativeModelFactory::from_trained(&model2, 4, move || build(&mut Rng::new(99)));
    let server = InferenceServer::start(factory, ServeConfig::default()).unwrap();
    let got = server.infer(ds.x.row(0).unwrap().to_vec()).unwrap();
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() < 1e-5, "served {g} vs trained {e}");
    }
    server.shutdown();
    std::fs::remove_file(path).ok();
}

#[test]
fn embedding_attention_pipeline_trains() {
    // Embedding + recorded mean-pool + Dense on a token task (the
    // examples/train_seq.rs pipeline, condensed).
    use minitensor::nn::Embedding;
    use minitensor::optim::{clip_grad_norm, AdaGrad};
    let mut rng = Rng::new(31);
    let emb = Embedding::new(16, 8, &mut rng);
    let head = Dense::new(8, 2, &mut rng);
    let mut params = emb.parameters();
    params.extend(head.parameters());
    let mut opt = AdaGrad::new(params.clone(), 0.2);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..120 {
        // class c sequences contain token c (0/1); fillers from 2..16
        let mut ids = Vec::new();
        let mut labels = Vec::new();
        for i in 0..32 {
            let c = (i % 2) as i32;
            for s in 0..4 {
                ids.push(if s == 0 { c } else { 2 + ((i * 7 + s) % 14) as i32 });
            }
            labels.push(c);
        }
        let ids = Tensor::from_vec_i32(ids, &[32 * 4]).unwrap();
        let labels = Tensor::from_vec_i32(labels, &[32]).unwrap();
        let tokens = emb.lookup(&ids).unwrap();
        let pooled = tokens
            .reshape(&[32, 4, 8])
            .unwrap()
            .mean_axis(1, false)
            .unwrap();
        let loss =
            losses::cross_entropy(&head.forward(&pooled, true).unwrap(), &labels).unwrap();
        last = loss.item().unwrap();
        first.get_or_insert(last);
        opt.zero_grad();
        loss.backward().unwrap();
        clip_grad_norm(&params, 10.0).unwrap();
        opt.step().unwrap();
    }
    assert!(
        last < first.unwrap() * 0.5,
        "embedding pipeline should learn: {:?} -> {last}",
        first
    );
}

#[test]
fn loss_curve_reproducible_from_seed() {
    let cfg = Config::parse(
        "[train]\ndataset = blobs\nn_examples = 128\ninput_side = 2\nhidden = 8\nclasses = 2\nsteps = 30\nbatch_size = 32\nseed = 99\n",
    )
    .unwrap();
    let tc = TrainConfig::from_config(&cfg).unwrap();
    let r1 = Trainer::new(tc.clone()).run().unwrap();
    let r2 = Trainer::new(tc).run().unwrap();
    assert_eq!(r1.losses, r2.losses, "same seed must reproduce the curve");
}
