//! The metrics registry end to end: lock-free shard merging under
//! concurrency, the serve stack's Prometheus endpoint scraped over real
//! TCP while requests are in flight, and the text exposition validated
//! with a hand-rolled parser (the crate stays zero-dependency even in
//! tests).
//!
//! The registry is process-global, so every test serializes on
//! [`guard`] — exact-delta assertions are only sound while nothing else
//! in this binary is executing tensor ops.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Mutex, MutexGuard};

use minitensor::coordinator::{InferenceServer, NativeModelFactory, ServeConfig};
use minitensor::data::Rng;
use minitensor::nn::{Activation, Dense, Sequential};
use minitensor::runtime::metrics;
use minitensor::tensor::Tensor;

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn counter(snap: &metrics::MetricsSnapshot, name: &str) -> u64 {
    snap.counters
        .iter()
        .find(|(k, _)| k == name)
        .map(|&(_, v)| v)
        .unwrap_or(0)
}

/// A fixed eager workload on a fresh thread: 50 adds → 50 dispatches'
/// worth of registry traffic, whatever the exact per-add cost is.
fn workload() {
    let a = Tensor::from_vec(vec![1.0; 4096], &[4096]).unwrap();
    let b = Tensor::from_vec(vec![2.0; 4096], &[4096]).unwrap();
    for _ in 0..50 {
        std::hint::black_box(a.add(&b).unwrap());
    }
}

#[test]
fn shard_merge_loses_no_increments_under_thread_hammer() {
    let _g = guard();
    metrics::set_enabled(true);
    // Calibrate: one thread's workload moves the merged counter by a
    // fixed amount (dispatch counting is per-op, independent of any
    // parallel chunking underneath).
    let before = metrics::snapshot();
    std::thread::spawn(workload).join().unwrap();
    let d1 = counter(&metrics::snapshot(), "minitensor_exec_dispatches_total")
        - counter(&before, "minitensor_exec_dispatches_total");
    assert!(d1 >= 50, "50 adds must dispatch at least 50 kernels: {d1}");

    // Hammer: t threads × the same workload must land exactly t × d1 on
    // the merged view — a lost per-thread shard or a racy merge shows up
    // as a shortfall here.
    for &t in &[1usize, 2, 4] {
        let before = counter(&metrics::snapshot(), "minitensor_exec_dispatches_total");
        let hs: Vec<_> = (0..t).map(|_| std::thread::spawn(workload)).collect();
        for h in hs {
            h.join().unwrap();
        }
        let after = counter(&metrics::snapshot(), "minitensor_exec_dispatches_total");
        assert_eq!(after - before, d1 * t as u64, "lost increments at t={t}");
    }
}

#[test]
fn disabled_registry_freezes_recording() {
    let _g = guard();
    metrics::set_enabled(false);
    let before = counter(&metrics::snapshot(), "minitensor_exec_dispatches_total");
    std::thread::spawn(workload).join().unwrap();
    let frozen = counter(&metrics::snapshot(), "minitensor_exec_dispatches_total");
    metrics::set_enabled(true);
    assert_eq!(frozen, before, "a disabled registry must drop increments");
    // Named metrics freeze too.
    metrics::set_enabled(false);
    metrics::counter_add("minitensor_test_disabled_total", 1);
    metrics::set_enabled(true);
    let snap = metrics::snapshot();
    assert!(
        !snap.counters.iter().any(|(k, _)| k == "minitensor_test_disabled_total"),
        "named increment must be dropped while disabled"
    );
    // And recording resumes after re-enabling.
    std::thread::spawn(workload).join().unwrap();
    assert!(counter(&metrics::snapshot(), "minitensor_exec_dispatches_total") > before);
}

/// Parse Prometheus text exposition: every non-comment line must be
/// `name[{labels}] value`. Returns the samples; panics on any line that
/// does not parse (that is the point).
fn parse_prometheus(body: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            // Comment lines must themselves be well-formed metadata.
            let mut parts = rest.split_whitespace();
            let kind = parts.next().expect("bare # line");
            assert!(
                kind == "HELP" || kind == "TYPE",
                "unknown comment kind in {line:?}"
            );
            assert!(parts.next().is_some(), "comment without metric name: {line:?}");
            continue;
        }
        let (name, val) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("sample line without value: {line:?}"));
        let v: f64 = val
            .parse()
            .unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
        out.insert(name.to_string(), v);
    }
    out
}

/// Blocking HTTP GET against the metrics endpoint; returns (head, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect to metrics endpoint");
    stream
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").as_bytes())
        .unwrap();
    let mut resp = Vec::new();
    stream.read_to_end(&mut resp).unwrap();
    let text = String::from_utf8(resp).expect("UTF-8 response");
    let (head, body) = text
        .split_once("\r\n\r\n")
        .expect("header/body separator");
    (head.to_string(), body.to_string())
}

#[test]
fn scrape_while_serving_is_parseable_and_monotonic() {
    let _g = guard();
    metrics::set_enabled(true);
    let factory = NativeModelFactory::new(4, || {
        let mut rng = Rng::new(1);
        Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 3, &mut rng))
    });
    let cfg = ServeConfig::new().metrics_port(0).build().unwrap();
    let server = std::sync::Arc::new(InferenceServer::start(factory, cfg).unwrap());
    let addr = server.metrics_addr().expect("metrics endpoint running");

    let infer_some = |n: usize| {
        let hs: Vec<_> = (0..n)
            .map(|i| {
                let s = server.clone();
                std::thread::spawn(move || {
                    s.infer(vec![i as f32, 0.0, 0.0, 0.0]).unwrap();
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    };

    infer_some(8);
    let (head1, body1) = http_get(addr, "/metrics");
    assert!(head1.starts_with("HTTP/1.1 200"), "{head1}");
    assert!(
        head1.contains("text/plain") && head1.contains("version=0.0.4"),
        "prometheus content type missing: {head1}"
    );
    let s1 = parse_prometheus(&body1);

    // The acceptance bar: one scrape covers ≥ 4 subsystems.
    for family in [
        "minitensor_exec_dispatches_total",   // exec tier
        "minitensor_pool_misses_total",       // allocator pool
        "minitensor_program_cache_hits_total", // graph program cache
        "minitensor_serve_requests_total",    // serve stack (mirrored)
    ] {
        assert!(s1.contains_key(family), "family {family} missing from scrape");
    }
    assert!(
        s1.contains_key("minitensor_serve_queue_depth_current"),
        "live queue-depth gauge missing"
    );
    // Serve latency mirrors in as a summary with quantiles + sum/count.
    assert!(
        s1.keys().any(|k| k.starts_with("minitensor_serve_latency{quantile=")),
        "latency summary missing: {:?}",
        s1.keys().collect::<Vec<_>>()
    );
    assert!(s1["minitensor_serve_requests_total"] >= 8.0);

    // More load, scrape again: every counter is monotone non-decreasing
    // and the request counter strictly advanced.
    infer_some(8);
    let (_, body2) = http_get(addr, "/metrics");
    let s2 = parse_prometheus(&body2);
    for (k, v1) in s1.iter().filter(|(k, _)| k.ends_with("_total")) {
        let v2 = s2.get(k).unwrap_or_else(|| panic!("counter {k} vanished"));
        assert!(v2 >= v1, "counter {k} went backwards: {v1} -> {v2}");
    }
    assert!(s2["minitensor_serve_requests_total"] >= s1["minitensor_serve_requests_total"] + 8.0);

    // JSON route serves the same snapshot shape; unknown routes 404.
    let (jh, jb) = http_get(addr, "/metrics.json");
    assert!(jh.starts_with("HTTP/1.1 200") && jh.contains("application/json"), "{jh}");
    assert!(jb.starts_with("{\"counters\":{"), "{jb}");
    let (nh, _) = http_get(addr, "/nope");
    assert!(nh.starts_with("HTTP/1.1 404"), "{nh}");

    // The endpoint dies with the server: connecting afterwards fails.
    let server = std::sync::Arc::try_unwrap(server)
        .unwrap_or_else(|_| panic!("all clients joined"));
    server.shutdown();
    assert!(
        TcpStream::connect(addr).is_err(),
        "endpoint must stop listening after shutdown"
    );
}
