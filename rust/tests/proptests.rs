//! Property-based tests (hand-rolled generator; proptest is not in the
//! offline vendor set). Each property is checked over many random
//! shapes/values from a seeded RNG — shrinking is approximated by testing
//! small shapes first.
//!
//! Invariants covered: broadcasting algebra, view round-trips, reduction
//! linearity, matmul algebra, autograd-vs-finite-difference on random
//! expressions, softmax simplex properties, and optimizer descent.

use minitensor::autograd::{gradcheck, Var};
use minitensor::data::Rng;
use minitensor::graph::LazyTensor;
use minitensor::runtime::parallel;
use minitensor::tensor::Tensor;

/// The worker-thread count is process-global: the fusion properties
/// that flip it serialize here so one test's "1-thread" reference can't
/// be computed under another test's 4-thread setting (which would turn
/// the 1-vs-4 invariance check into a vacuous 4-vs-4), and so the
/// restore can't race.
fn nt_lock() -> std::sync::MutexGuard<'static, ()> {
    use std::sync::{Mutex, OnceLock};
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Random shape with rank 1..=4, numel ≤ 512 (small first).
fn random_shape(rng: &mut Rng, case: usize) -> Vec<usize> {
    let rank = 1 + (case % 4).min(rng.next_below(4) as usize);
    let budget = if case < 8 { 8 } else { 512 };
    let mut dims = Vec::with_capacity(rank);
    let mut numel = 1usize;
    for _ in 0..rank {
        let max = (budget / numel).max(1).min(8);
        let d = 1 + rng.next_below(max as u32) as usize;
        dims.push(d);
        numel *= d;
    }
    dims
}

fn random_tensor(rng: &mut Rng, dims: &[usize]) -> Tensor {
    Tensor::randn(dims, 0.0, 1.0, rng)
}

#[test]
fn prop_add_commutative_and_associative() {
    let mut rng = Rng::new(100);
    for case in 0..50 {
        let dims = random_shape(&mut rng, case);
        let a = random_tensor(&mut rng, &dims);
        let b = random_tensor(&mut rng, &dims);
        let c = random_tensor(&mut rng, &dims);
        let ab = a.add(&b).unwrap();
        let ba = b.add(&a).unwrap();
        assert!(ab.allclose(&ba, 1e-6, 1e-6), "commutativity at {dims:?}");
        let left = ab.add(&c).unwrap();
        let right = a.add(&b.add(&c).unwrap()).unwrap();
        assert!(left.allclose(&right, 1e-4, 1e-4), "associativity at {dims:?}");
    }
}

#[test]
fn prop_mul_distributes_over_add() {
    let mut rng = Rng::new(101);
    for case in 0..50 {
        let dims = random_shape(&mut rng, case);
        let a = random_tensor(&mut rng, &dims);
        let b = random_tensor(&mut rng, &dims);
        let c = random_tensor(&mut rng, &dims);
        let left = a.mul(&b.add(&c).unwrap()).unwrap();
        let right = a.mul(&b).unwrap().add(&a.mul(&c).unwrap()).unwrap();
        assert!(left.allclose(&right, 1e-4, 1e-4), "{dims:?}");
    }
}

#[test]
fn prop_broadcast_equals_materialized() {
    // x op broadcast(b) == x op materialize(broadcast(b)) for all ops.
    let mut rng = Rng::new(102);
    for _case in 0..40 {
        let rows = 1 + rng.next_below(6) as usize;
        let cols = 1 + rng.next_below(6) as usize;
        let x = random_tensor(&mut rng, &[rows, cols]);
        let b = random_tensor(&mut rng, &[cols]);
        let virt = x.add(&b).unwrap();
        let mat = x
            .add(&b.broadcast_to(&[rows, cols]).unwrap().contiguous())
            .unwrap();
        assert!(virt.allclose(&mat, 1e-6, 1e-6));
    }
}

#[test]
fn prop_reshape_transpose_roundtrip_preserves_values() {
    let mut rng = Rng::new(103);
    for case in 0..50 {
        let dims = random_shape(&mut rng, case);
        let t = random_tensor(&mut rng, &dims);
        // flatten → reshape back
        let rt = t.flatten().unwrap().reshape(&dims).unwrap();
        assert_eq!(rt.to_vec(), t.to_vec());
        // double transpose is identity (rank ≥ 2)
        if dims.len() >= 2 {
            let tt = t.transpose(0, 1).unwrap().transpose(0, 1).unwrap();
            assert_eq!(tt.to_vec(), t.to_vec());
        }
    }
}

#[test]
fn prop_sum_axis_composition_equals_total_sum() {
    // Reducing every axis one at a time equals the full reduction.
    let mut rng = Rng::new(104);
    for case in 0..40 {
        let dims = random_shape(&mut rng, case);
        let t = random_tensor(&mut rng, &dims);
        let total = t.sum().item().unwrap();
        let mut cur = t.clone();
        while cur.rank() > 0 {
            cur = cur.sum_axis(0, false).unwrap();
        }
        let via_axes = cur.item().unwrap();
        assert!(
            (total - via_axes).abs() <= 1e-3 * total.abs().max(1.0),
            "{dims:?}: {total} vs {via_axes}"
        );
    }
}

#[test]
fn prop_mean_is_sum_over_numel() {
    let mut rng = Rng::new(105);
    for case in 0..30 {
        let dims = random_shape(&mut rng, case);
        let t = random_tensor(&mut rng, &dims);
        let mean = t.mean().item().unwrap();
        let sum = t.sum().item().unwrap();
        assert!((mean - sum / t.numel() as f32).abs() < 1e-4);
    }
}

#[test]
fn prop_matmul_associative_and_identity() {
    let mut rng = Rng::new(106);
    for _case in 0..25 {
        let m = 1 + rng.next_below(8) as usize;
        let k = 1 + rng.next_below(8) as usize;
        let n = 1 + rng.next_below(8) as usize;
        let p = 1 + rng.next_below(8) as usize;
        let a = random_tensor(&mut rng, &[m, k]);
        let b = random_tensor(&mut rng, &[k, n]);
        let c = random_tensor(&mut rng, &[n, p]);
        let left = a.matmul(&b).unwrap().matmul(&c).unwrap();
        let right = a.matmul(&b.matmul(&c).unwrap()).unwrap();
        assert!(left.allclose(&right, 1e-2, 1e-2), "({m},{k},{n},{p})");
        // identity
        let ai = a.matmul(&Tensor::eye(k)).unwrap();
        assert!(ai.allclose(&a, 1e-5, 1e-5));
    }
}

#[test]
fn prop_matmul_transpose_identity() {
    // (A·B)ᵀ == Bᵀ·Aᵀ
    let mut rng = Rng::new(107);
    for _ in 0..25 {
        let m = 1 + rng.next_below(10) as usize;
        let k = 1 + rng.next_below(10) as usize;
        let n = 1 + rng.next_below(10) as usize;
        let a = random_tensor(&mut rng, &[m, k]);
        let b = random_tensor(&mut rng, &[k, n]);
        let left = a.matmul(&b).unwrap().t().unwrap().contiguous();
        let right = b.t().unwrap().matmul(&a.t().unwrap()).unwrap();
        assert!(left.allclose(&right, 1e-4, 1e-4));
    }
}

#[test]
fn prop_softmax_rows_on_simplex() {
    let mut rng = Rng::new(108);
    for _ in 0..30 {
        let rows = 1 + rng.next_below(10) as usize;
        let cols = 2 + rng.next_below(12) as usize;
        let t = Tensor::randn(&[rows, cols], 0.0, 3.0, &mut rng);
        let p = t.softmax().unwrap();
        assert!(p.iter().all(|v| (0.0..=1.0).contains(&v)));
        let sums = p.sum_axis(-1, false).unwrap();
        assert!(sums.allclose(&Tensor::ones(&[rows]), 1e-4, 1e-4));
    }
}

#[test]
fn prop_gradcheck_random_expressions() {
    // Random smooth expression trees vs finite differences (eq 11).
    let mut rng = Rng::new(109);
    for case in 0..12 {
        let dims = vec![2 + (case % 3), 3];
        let x0 = Tensor::randn(&dims, 0.0, 0.7, &mut rng);
        let which = rng.next_below(5);
        let report = gradcheck(
            move |v: &Var| {
                let y = match which {
                    0 => v.tanh().square(),
                    1 => v.sigmoid().mul_scalar(3.0),
                    2 => v.exp().log(),
                    3 => v.square().add_scalar(1.0).sqrt(),
                    _ => v.gelu(),
                };
                y.sum()
            },
            &x0,
            1e-3,
            2e-2,
        )
        .unwrap();
        assert!(report.pass, "case {case} ({which}): {report:?}");
    }
}

#[test]
fn prop_bias_grad_equals_batch_sum() {
    // For y = x + b (bias broadcast), dL/db with L = Σ w⊙y must be Σ_batch w.
    let mut rng = Rng::new(110);
    for _ in 0..20 {
        let rows = 1 + rng.next_below(8) as usize;
        let cols = 1 + rng.next_below(8) as usize;
        let x = Var::from_tensor(Tensor::randn(&[rows, cols], 0.0, 1.0, &mut rng), false);
        let b = Var::from_tensor(Tensor::randn(&[cols], 0.0, 1.0, &mut rng), true);
        let w = Tensor::randn(&[rows, cols], 0.0, 1.0, &mut rng);
        x.add(&b)
            .unwrap()
            .mul_mask(&w)
            .unwrap()
            .sum()
            .unwrap()
            .backward()
            .unwrap();
        let expect = w.sum_axis(0, false).unwrap();
        assert!(b.grad().unwrap().allclose(&expect, 1e-4, 1e-4));
    }
}

#[test]
fn prop_view_ops_never_copy() {
    let mut rng = Rng::new(111);
    for case in 0..30 {
        let mut dims = random_shape(&mut rng, case);
        if dims.len() < 2 {
            dims.push(2);
        }
        let t = random_tensor(&mut rng, &dims);
        assert!(t.shares_storage(&t.transpose(0, 1).unwrap()));
        assert!(t.shares_storage(&t.unsqueeze(0).unwrap()));
        assert!(t.shares_storage(&t.narrow(0, 0, dims[0]).unwrap()));
        let flat_numel = t.numel();
        assert!(t.shares_storage(&t.reshape(&[flat_numel]).unwrap()));
    }
}

/// Random expression DAG over {add, mul, neg, relu, exp} with
/// broadcastable random leaf shapes, built simultaneously as a lazy
/// recording and as the eager op chain. Returns both so properties can
/// compare them bit for bit.
fn gen_fusion_case(rng: &mut Rng, dims: &[usize], depth: usize) -> (LazyTensor, Tensor) {
    if depth == 0 || rng.next_below(4) == 0 {
        // Leaf: drop random leading axes and shrink random axes to 1 so
        // broadcasting happens inside the DAG.
        let keep = rng.next_below(dims.len() as u32 + 1) as usize;
        let mut shape: Vec<usize> = dims[keep..].to_vec();
        for d in shape.iter_mut() {
            if rng.next_below(3) == 0 {
                *d = 1;
            }
        }
        let t = Tensor::randn(&shape, 0.0, 1.0, rng);
        return (t.lazy(), t);
    }
    match rng.next_below(5) {
        0 => {
            let (l1, t1) = gen_fusion_case(rng, dims, depth - 1);
            let (l2, t2) = gen_fusion_case(rng, dims, depth - 1);
            (l1.add(&l2).unwrap(), t1.add(&t2).unwrap())
        }
        1 => {
            let (l1, t1) = gen_fusion_case(rng, dims, depth - 1);
            let (l2, t2) = gen_fusion_case(rng, dims, depth - 1);
            (l1.mul(&l2).unwrap(), t1.mul(&t2).unwrap())
        }
        2 => {
            let (l, t) = gen_fusion_case(rng, dims, depth - 1);
            (l.neg(), t.neg())
        }
        3 => {
            let (l, t) = gen_fusion_case(rng, dims, depth - 1);
            (l.relu(), t.relu())
        }
        _ => {
            let (l, t) = gen_fusion_case(rng, dims, depth - 1);
            (l.exp(), t.exp())
        }
    }
}

fn assert_bits_eq(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.dims(), b.dims(), "{ctx}: shape");
    let (av, bv) = (a.to_vec(), b.to_vec());
    for i in 0..av.len() {
        assert_eq!(av[i].to_bits(), bv[i].to_bits(), "{ctx}: elem {i}");
    }
}

#[test]
fn prop_fused_eval_bitwise_equals_eager_chain() {
    // Random DAGs of {add, mul, neg, relu, exp, sum}: fused eval() must
    // be bitwise-equal to the eager op chain, at 1 and at 4 threads.
    let _guard = nt_lock();
    let mut rng = Rng::new(200);
    let before = parallel::num_threads();
    for case in 0..40 {
        let dims = random_shape(&mut rng, case);
        let (lazy, eager) = gen_fusion_case(&mut rng, &dims, 2 + case % 3);
        let with_sum = rng.next_below(2) == 0;
        let (lazy, eager) = if with_sum {
            (lazy.sum(), eager.sum())
        } else {
            (lazy, eager)
        };
        for threads in [1usize, 4] {
            parallel::set_num_threads(threads);
            let fused = lazy.eval().unwrap();
            let replay = lazy.eval_eager().unwrap();
            assert_bits_eq(
                &fused,
                &eager,
                &format!("case {case} ({dims:?}, sum={with_sum}, t={threads}) vs eager chain"),
            );
            assert_bits_eq(
                &fused,
                &replay,
                &format!("case {case} ({dims:?}, sum={with_sum}, t={threads}) vs replay"),
            );
        }
    }
    parallel::set_num_threads(before);
}

#[test]
fn prop_fused_reduce_thread_invariant_on_large_inputs() {
    // Multi-chunk fused sums (n > REDUCE_CHUNK) must be bit-identical
    // across thread counts and equal to the eager chain at each count.
    let _guard = nt_lock();
    let mut rng = Rng::new(201);
    let before = parallel::num_threads();
    for &n in &[40_000usize, 100_000] {
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let value_at = |threads: usize| {
            parallel::set_num_threads(threads);
            let (la, lb) = (a.lazy(), b.lazy());
            let fused = la
                .mul(&lb)
                .unwrap()
                .add(&la)
                .unwrap()
                .relu()
                .sum()
                .eval()
                .unwrap()
                .item()
                .unwrap();
            let eager = a
                .mul(&b)
                .unwrap()
                .add(&a)
                .unwrap()
                .relu()
                .sum()
                .item()
                .unwrap();
            assert_eq!(
                fused.to_bits(),
                eager.to_bits(),
                "fused vs eager at {threads} threads (n={n})"
            );
            fused
        };
        let v1 = value_at(1);
        let v2 = value_at(2);
        let v4 = value_at(4);
        assert_eq!(v1.to_bits(), v2.to_bits(), "1 vs 2 threads (n={n})");
        assert_eq!(v1.to_bits(), v4.to_bits(), "1 vs 4 threads (n={n})");
    }
    parallel::set_num_threads(before);
}

#[test]
fn prop_fused_row_pipelines_bitwise_equal_eager() {
    // Random row-pipeline DAGs — a random elementwise prefix feeding a
    // last-axis reduction (sum/mean/max/min, keepdim or not) or the full
    // softmax pattern (x - rowmax -> exp -> / rowsum) — must be
    // bitwise-equal to the eager op chain at 1 and at 4 threads.
    let _guard = nt_lock();
    let mut rng = Rng::new(203);
    let before = parallel::num_threads();
    for case in 0..30 {
        let dims = random_shape(&mut rng, case);
        // Anchor to the full shape so the virtual result keeps rank >= 1
        // (gen_fusion_case leaves may drop axes).
        let anchor = Tensor::randn(&dims, 0.0, 1.0, &mut rng);
        let (lp, tp) = gen_fusion_case(&mut rng, &dims, 1 + case % 3);
        let (lazy0, eager0) = (lp.add(&anchor.lazy()).unwrap(), tp.add(&anchor).unwrap());
        let keepdim = rng.next_below(2) == 0;
        let softmax_case = rng.next_below(4) == 0;
        let (lazy, eager) = if softmax_case {
            // Softmax pattern over the pipeline: shared nodes, two axis
            // reduces, and a broadcast divide.
            let lm = lazy0.max_axis(-1, true).unwrap();
            let le = lazy0.sub(&lm).unwrap().exp();
            let ls = le.sum_axis(-1, true).unwrap();
            let lazy = le.div(&ls).unwrap();
            let em = eager0.max_axis(-1, true).unwrap();
            let ee = eager0.sub(&em).unwrap().exp();
            let es = ee.sum_axis(-1, true).unwrap();
            (lazy, ee.div(&es).unwrap())
        } else {
            match rng.next_below(4) {
                0 => (
                    lazy0.sum_axis(-1, keepdim).unwrap(),
                    eager0.sum_axis(-1, keepdim).unwrap(),
                ),
                1 => (
                    lazy0.mean_axis(-1, keepdim).unwrap(),
                    eager0.mean_axis(-1, keepdim).unwrap(),
                ),
                2 => (
                    lazy0.max_axis(-1, keepdim).unwrap(),
                    eager0.max_axis(-1, keepdim).unwrap(),
                ),
                _ => (
                    lazy0.min_axis(-1, keepdim).unwrap(),
                    eager0.min_axis(-1, keepdim).unwrap(),
                ),
            }
        };
        for threads in [1usize, 4] {
            parallel::set_num_threads(threads);
            let fused = lazy.eval().unwrap();
            let replay = lazy.eval_eager().unwrap();
            let ctx = format!(
                "case {case} ({dims:?}, softmax={softmax_case}, keepdim={keepdim}, t={threads})"
            );
            assert_bits_eq(&fused, &eager, &format!("{ctx} vs eager chain"));
            assert_bits_eq(&fused, &replay, &format!("{ctx} vs replay"));
        }
    }
    parallel::set_num_threads(before);
}

#[test]
fn prop_fused_var_grads_match_eager_tape() {
    // Var::fused gradients equal the eager Var chain's gradients on
    // random inputs (same VJP rules, replayed).
    let mut rng = Rng::new(202);
    for _case in 0..10 {
        let rows = 1 + rng.next_below(6) as usize;
        let cols = 1 + rng.next_below(6) as usize;
        let a0 = Tensor::randn(&[rows, cols], 0.0, 1.0, &mut rng);
        let b0 = Tensor::randn(&[cols], 0.0, 1.0, &mut rng);

        let (ae, be) = (
            Var::from_tensor(a0.clone(), true),
            Var::from_tensor(b0.clone(), true),
        );
        ae.mul(&be)
            .unwrap()
            .relu()
            .sum()
            .unwrap()
            .backward()
            .unwrap();

        let (af, bf) = (
            Var::from_tensor(a0, true),
            Var::from_tensor(b0, true),
        );
        Var::fused(&[&af, &bf], |l| Ok(l[0].mul(&l[1])?.relu().sum()))
            .unwrap()
            .backward()
            .unwrap();

        assert!(af
            .grad()
            .unwrap()
            .allclose(&ae.grad().unwrap(), 1e-6, 1e-6));
        assert!(bf
            .grad()
            .unwrap()
            .allclose(&be.grad().unwrap(), 1e-6, 1e-6));
    }
}

#[test]
fn prop_sgd_descends_any_psd_quadratic() {
    // L = ||Aθ||² is convex; SGD with small lr must descend monotonically.
    let mut rng = Rng::new(112);
    for _ in 0..10 {
        let d = 2 + rng.next_below(4) as usize;
        let a = Tensor::randn(&[d, d], 0.0, 1.0, &mut rng);
        let theta = Var::from_tensor(Tensor::randn(&[d, 1], 0.0, 1.0, &mut rng), true);
        let mut opt = minitensor::optim::Sgd::new(vec![theta.clone()], 0.01);
        let mut last = f32::INFINITY;
        for _ in 0..30 {
            use minitensor::optim::Optimizer;
            opt.zero_grad();
            let loss = Var::from_tensor(a.clone(), false)
                .matmul(&theta)
                .unwrap()
                .square()
                .sum()
                .unwrap();
            let l = loss.item().unwrap();
            assert!(l <= last * 1.001, "ascent detected: {last} -> {l}");
            last = l;
            loss.backward().unwrap();
            opt.step().unwrap();
        }
    }
}
