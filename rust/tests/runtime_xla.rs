//! Integration tests for the AOT path: rust loads the HLO-text artifacts
//! produced by `make artifacts` and executes them via PJRT, checking
//! numerics against the native engine.
//!
//! These tests require building with `--features xla` (the whole file is
//! compiled out otherwise) and `artifacts/` to exist (run
//! `make artifacts`); they are skipped gracefully when artifacts are
//! missing so `cargo test` works standalone.

#![cfg(feature = "xla")]

use minitensor::data::Rng;
use minitensor::runtime::Engine;
use minitensor::tensor::Tensor;

fn engine() -> Option<Engine> {
    match Engine::cpu(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping xla test (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_entry_points() {
    let Some(engine) = engine() else { return };
    let names = engine.artifact_names();
    for expected in [
        "mlp_forward",
        "mlp_loss",
        "mlp_train_step",
        "matmul_256",
        "elementwise_1m",
        "reduction_1m",
    ] {
        assert!(names.iter().any(|n| n == expected), "missing {expected}");
    }
}

#[test]
fn xla_matmul_matches_native_engine() {
    let Some(mut engine) = engine() else { return };
    let mut rng = Rng::new(1);
    let a = Tensor::randn(&[256, 256], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[256, 256], 0.0, 1.0, &mut rng);
    let xla_out = engine.run("matmul_256", &[&a, &b]).unwrap();
    let native = a.matmul(&b).unwrap();
    assert_eq!(xla_out.len(), 1);
    assert!(
        xla_out[0].allclose(&native, 1e-3, 1e-3),
        "xla and native matmul disagree"
    );
}

#[test]
fn xla_elementwise_matches_native() {
    let Some(mut engine) = engine() else { return };
    let mut rng = Rng::new(2);
    let n = 1_048_576;
    let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
    // artifact computes relu(a*b + a)
    let xla_out = engine.run("elementwise_1m", &[&a, &b]).unwrap();
    let native = a.mul(&b).unwrap().add(&a).unwrap().relu();
    assert!(xla_out[0].allclose(&native, 1e-4, 1e-5));
}

#[test]
fn xla_reduction_matches_native() {
    let Some(mut engine) = engine() else { return };
    let mut rng = Rng::new(3);
    let a = Tensor::randn(&[1_048_576], 0.0, 1.0, &mut rng);
    let out = engine.run("reduction_1m", &[&a]).unwrap();
    assert_eq!(out.len(), 2);
    let sum_native = a.sum().item().unwrap();
    let mean_native = a.mean().item().unwrap();
    assert!(
        (out[0].item().unwrap() - sum_native).abs() < 0.5,
        "sum: {} vs {}",
        out[0].item().unwrap(),
        sum_native
    );
    assert!((out[1].item().unwrap() - mean_native).abs() < 1e-4);
}

#[test]
fn xla_forward_matches_native_dense_stack() {
    let Some(mut engine) = engine() else { return };
    let art = engine.manifest().get("mlp_forward").unwrap().clone();
    let mut rng = Rng::new(4);
    let inputs: Vec<Tensor> = art
        .input_shapes
        .iter()
        .map(|s| Tensor::randn(s, 0.0, 0.5, &mut rng))
        .collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let xla_logits = engine.run("mlp_forward", &refs).unwrap();

    // Native replica: x · W1ᵀ + b1 → relu → … → logits
    let x = &inputs[0];
    let mut h = x.clone();
    let n_layers = (inputs.len() - 1) / 2;
    for i in 0..n_layers {
        let w = &inputs[1 + 2 * i];
        let b = &inputs[2 + 2 * i];
        h = h.matmul_nt(w).unwrap().add(b).unwrap();
        if i < n_layers - 1 {
            h = h.relu();
        }
    }
    assert!(
        xla_logits[0].allclose(&h, 1e-3, 1e-3),
        "xla forward != native forward"
    );
}

#[test]
fn xla_train_step_decreases_loss() {
    let Some(mut engine) = engine() else { return };
    let art = engine.manifest().get("mlp_train_step").unwrap().clone();
    let mut rng = Rng::new(5);
    let x = Tensor::rand(&art.input_shapes[0], 0.0, 1.0, &mut rng);
    // labels: one-hot of i % classes
    let classes = art.input_shapes[1][1];
    let batch = art.input_shapes[1][0];
    let labels: Vec<i32> = (0..batch).map(|i| (i % classes) as i32).collect();
    let y = Tensor::one_hot(
        &Tensor::from_vec_i32(labels, &[batch]).unwrap(),
        classes,
    )
    .unwrap();
    let mut params: Vec<Tensor> = art.input_shapes[2..]
        .iter()
        .map(|s| {
            if s.len() == 2 {
                minitensor::nn::kaiming_uniform(s, s[1], &mut rng)
            } else {
                Tensor::zeros(s)
            }
        })
        .collect();

    let mut losses = Vec::new();
    for _ in 0..10 {
        let mut inputs: Vec<&Tensor> = vec![&x, &y];
        inputs.extend(params.iter());
        let mut outs = engine.run("mlp_train_step", &inputs).unwrap();
        losses.push(outs.remove(0).item().unwrap());
        params = outs;
    }
    assert!(
        losses[9] < losses[0],
        "loss should descend on a fixed batch: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn xla_attention_matches_native_composition() {
    let Some(mut engine) = engine() else { return };
    if engine.manifest().get("attention_128x64").is_err() {
        eprintln!("skipping: attention artifact not built yet");
        return;
    }
    let mut rng = Rng::new(6);
    let q = Tensor::randn(&[128, 64], 0.0, 1.0, &mut rng);
    let k = Tensor::randn(&[128, 64], 0.0, 1.0, &mut rng);
    let v = Tensor::randn(&[128, 64], 0.0, 1.0, &mut rng);
    let xla_out = engine.run("attention_128x64", &[&q, &k, &v]).unwrap();
    let native = q.attention(&k, &v).unwrap();
    assert!(
        xla_out[0].allclose(&native, 1e-3, 1e-3),
        "fused Pallas attention != native composition"
    );
}

#[test]
fn wrong_input_shape_is_rejected() {
    let Some(mut engine) = engine() else { return };
    let a = Tensor::zeros(&[2, 2]);
    let b = Tensor::zeros(&[2, 2]);
    assert!(engine.run("matmul_256", &[&a, &b]).is_err());
    assert!(engine.run("matmul_256", &[&a]).is_err());
    assert!(engine.run("nonexistent", &[]).is_err());
}
