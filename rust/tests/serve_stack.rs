//! Serving-stack integration tests: the multi-worker continuous-batching
//! server's correctness properties — replica equivalence, admission
//! control (fast-reject + deadline shedding), graceful drain, the warm
//! per-worker program cache, and the recovery invariants (panic
//! isolation, supervised restart, degraded operation).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use minitensor::coordinator::{
    BatchModel, FactoryFn, InferenceServer, ModelFactory, NativeModelFactory, ServeConfig,
};
use minitensor::data::Rng;
use minitensor::error::{Error, Result};
use minitensor::nn::{Activation, Dense, Sequential};
use minitensor::tensor::Tensor;

fn mlp_factory(in_features: usize) -> NativeModelFactory {
    NativeModelFactory::new(in_features, move || {
        let mut rng = Rng::new(7);
        Sequential::new()
            .add(Dense::new(in_features, 16, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(16, 4, &mut rng))
    })
}

/// A model whose forward takes a fixed wall-clock time — lets the tests
/// hold a worker busy deterministically.
struct Sleepy {
    delay: Duration,
}

impl BatchModel for Sleepy {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        let b = x.dims()[0];
        Tensor::from_vec(vec![0.0; b], &[b, 1])
    }
    fn in_features(&self) -> usize {
        2
    }
}

fn sleepy_factory(delay: Duration) -> FactoryFn<impl Fn(usize) -> Result<Box<dyn BatchModel>>> {
    FactoryFn::new(2, move |_worker| {
        let m: Box<dyn BatchModel> = Box::new(Sleepy { delay });
        Ok(m)
    })
}

#[test]
fn multi_worker_replies_bitwise_match_single_worker() {
    // Per-request outputs must not depend on how requests were batched
    // or which replica ran them: per-row accumulation order is batch-
    // composition-invariant, and every replica holds byte-identical
    // weights (the factory snapshots one prototype).
    let in_features = 8;
    let n_requests = 48;
    let mut rng = Rng::new(99);
    let requests: Vec<Vec<f32>> = (0..n_requests)
        .map(|_| (0..in_features).map(|_| rng.next_f32()).collect())
        .collect();

    // Reference: single worker, forced singleton batches.
    let cfg1 = ServeConfig::new()
        .workers(1)
        .max_batch(1)
        .max_wait_ms(0)
        .build()
        .unwrap();
    let server1 = InferenceServer::start(mlp_factory(in_features), cfg1).unwrap();
    let expected: Vec<Vec<u32>> = requests
        .iter()
        .map(|r| {
            server1
                .infer(r.clone())
                .unwrap()
                .iter()
                .map(|v| v.to_bits())
                .collect()
        })
        .collect();
    server1.shutdown();

    // 3 workers, concurrent clients, real batch fusion.
    let cfg3 = ServeConfig::new()
        .workers(3)
        .max_batch(8)
        .max_wait_ms(2)
        .build()
        .unwrap();
    let server3 = Arc::new(InferenceServer::start(mlp_factory(in_features), cfg3).unwrap());
    let handles: Vec<_> = requests
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let s = server3.clone();
            let r = r.clone();
            std::thread::spawn(move || (i, s.infer(r).unwrap()))
        })
        .collect();
    for h in handles {
        let (i, got) = h.join().unwrap();
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(
            got_bits, expected[i],
            "request {i}: multi-worker reply differs from single-worker"
        );
    }
    let stats = server3.stats();
    assert_eq!(stats.requests, n_requests as u64);
    assert_eq!(stats.worker_batches.len(), 3);
    assert_eq!(
        stats.worker_batches.iter().sum::<u64>(),
        stats.batches,
        "per-worker batch series must sum to the total"
    );
}

#[test]
fn saturated_queue_fast_rejects_with_overloaded() {
    // Pipeline capacity with workers=1, max_batch=1, queue_depth=1:
    // one executing + two queued batches + one in the dispatcher's hand
    // + one admission slot. Eight simultaneous clients must overflow it.
    let cfg = ServeConfig::new()
        .workers(1)
        .max_batch(1)
        .max_wait_ms(0)
        .queue_depth(1)
        .build()
        .unwrap();
    let server = Arc::new(
        InferenceServer::start(sleepy_factory(Duration::from_millis(150)), cfg).unwrap(),
    );
    let barrier = Arc::new(Barrier::new(8));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let s = server.clone();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                s.infer(vec![0.0, 0.0])
            })
        })
        .collect();
    let mut overloaded = 0;
    let mut ok = 0;
    for h in handles {
        match h.join().unwrap() {
            Ok(_) => ok += 1,
            Err(Error::Overloaded { queue_depth }) => {
                assert_eq!(queue_depth, 1);
                overloaded += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(ok >= 1, "some requests must be admitted");
    assert!(
        overloaded >= 1,
        "a saturated queue must fast-reject ({ok} ok / {overloaded} overloaded)"
    );
    assert!(server.stats().rejected >= overloaded as u64);
}

#[test]
fn expired_deadline_requests_are_shed() {
    let cfg = ServeConfig::new()
        .workers(1)
        .max_batch(1)
        .max_wait_ms(0)
        .queue_depth(16)
        .build()
        .unwrap();
    let server = Arc::new(
        InferenceServer::start(sleepy_factory(Duration::from_millis(150)), cfg).unwrap(),
    );
    // Occupy the only worker…
    let s = server.clone();
    let busy = std::thread::spawn(move || s.infer(vec![1.0, 1.0]));
    std::thread::sleep(Duration::from_millis(40));
    // …then submit a request that expires long before the worker frees.
    let shed = server.infer_deadline(vec![2.0, 2.0], Duration::from_millis(10));
    match shed {
        Err(Error::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(busy.join().unwrap().is_ok(), "undeadlined request completes");
    assert!(server.stats().shed >= 1);
}

#[test]
fn drain_answers_all_admitted_requests_then_refuses_new() {
    let cfg = ServeConfig::new()
        .workers(1)
        .max_batch(1)
        .max_wait_ms(0)
        .queue_depth(32)
        .build()
        .unwrap();
    let server = Arc::new(
        InferenceServer::start(sleepy_factory(Duration::from_millis(60)), cfg).unwrap(),
    );
    let handles: Vec<_> = (0..5)
        .map(|_| {
            let s = server.clone();
            std::thread::spawn(move || s.infer(vec![0.0, 0.0]))
        })
        .collect();
    // Admission is instantaneous next to the 60 ms forwards: by now all
    // five are in flight somewhere between the queue and the worker.
    std::thread::sleep(Duration::from_millis(30));
    server.drain();
    // New work is refused immediately…
    assert!(server.infer(vec![0.0, 0.0]).is_err(), "post-drain infer must fail");
    // …but every admitted request still gets its real reply.
    for h in handles {
        let reply = h.join().unwrap();
        assert!(reply.is_ok(), "admitted request dropped during drain: {reply:?}");
    }
    assert_eq!(server.stats().requests, 5);
}

#[test]
fn warm_worker_hits_program_cache_on_repeat_batches() {
    // PR 5's compiled-Program cache is per-thread; a worker that owns
    // its replica keeps it warm, so identical batch shapes skip region
    // partitioning after the first forward. The workers surface their
    // thread-local engine counters through the server metrics.
    let cfg = ServeConfig::new()
        .workers(1)
        .max_batch(1)
        .max_wait_ms(0)
        .build()
        .unwrap();
    let server = InferenceServer::start(mlp_factory(4), cfg).unwrap();
    for _ in 0..4 {
        server.infer(vec![0.1, 0.2, 0.3, 0.4]).unwrap();
    }
    let hits = server.metrics().counter("serve.program_cache_hits");
    assert!(
        hits >= 2,
        "repeat identical batches on a warm worker must hit the program cache (hits={hits})"
    );
    let stats = server.stats();
    assert_eq!(stats.requests, 4);
    assert!(stats.p95_latency_ms >= stats.p50_latency_ms);
    server.shutdown();
}

/// Wraps a real replica; panics on a forward when the shared flag is
/// set (taking the flag, so exactly one forward crashes per arming).
struct CrashWrap {
    inner: Box<dyn BatchModel>,
    crash: Arc<AtomicBool>,
}

impl BatchModel for CrashWrap {
    fn forward_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        if self.crash.swap(false, Ordering::SeqCst) {
            panic!("injected replica crash (test)");
        }
        self.inner.forward_batch(x)
    }
    fn in_features(&self) -> usize {
        self.inner.in_features()
    }
}

#[test]
fn worker_panic_is_contained_and_the_rebuilt_replica_is_byte_equivalent() {
    let in_features = 8;
    let mut rng = Rng::new(123);
    let requests: Vec<Vec<f32>> = (0..24)
        .map(|_| (0..in_features).map(|_| rng.next_f32()).collect())
        .collect();

    // Reference outputs from a plain single-worker server.
    let cfg1 = ServeConfig::new()
        .workers(1)
        .max_batch(1)
        .max_wait_ms(0)
        .build()
        .unwrap();
    let server1 = InferenceServer::start(mlp_factory(in_features), cfg1).unwrap();
    let expected: Vec<Vec<u32>> = requests
        .iter()
        .map(|r| {
            server1
                .infer(r.clone())
                .unwrap()
                .iter()
                .map(|v| v.to_bits())
                .collect()
        })
        .collect();
    server1.shutdown();

    let crash = Arc::new(AtomicBool::new(false));
    let inner = Arc::new(mlp_factory(in_features));
    let flag = crash.clone();
    let factory = FactoryFn::new(in_features, move |worker| {
        let m: Box<dyn BatchModel> = Box::new(CrashWrap {
            inner: inner.build(worker)?,
            crash: flag.clone(),
        });
        Ok(m)
    });
    let cfg = ServeConfig::new()
        .workers(3)
        .max_batch(8)
        .max_wait_ms(1)
        .restart_backoff_ms(1)
        .build()
        .unwrap();
    let server = Arc::new(InferenceServer::start(factory, cfg).unwrap());

    // Crash exactly one forward: the victim request gets a definite,
    // retryable reply — not a hang, not a dead server.
    crash.store(true, Ordering::SeqCst);
    match server.infer(requests[0].clone()) {
        Err(Error::WorkerCrashed { detail, .. }) => {
            assert!(detail.contains("injected replica crash"), "{detail}");
        }
        other => panic!("expected WorkerCrashed, got {other:?}"),
    }

    // The crashed worker rebuilds its replica in place.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.stats().worker_restarts < 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = server.stats();
    assert_eq!(stats.worker_crashes, 1);
    assert!(stats.worker_restarts >= 1, "replica must be rebuilt: {stats:?}");
    assert_eq!(stats.health, "live", "a recovered server is healthy");
    assert_eq!(stats.workers_alive, 3, "in-place rebuild keeps all slots live");

    // Post-recovery, all 3 workers — including the rebuilt replica —
    // must stay byte-equivalent to the single-worker reference.
    let handles: Vec<_> = requests
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let s = server.clone();
            let r = r.clone();
            std::thread::spawn(move || (i, s.infer(r).unwrap()))
        })
        .collect();
    for h in handles {
        let (i, got) = h.join().unwrap();
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, expected[i], "request {i} diverges after restart");
    }
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
}

/// A replica that panics on every forward — for testing the slot-lost
/// (degraded) path where rebuilding can't help.
struct AlwaysCrash;

impl BatchModel for AlwaysCrash {
    fn forward_batch(&mut self, _x: &Tensor) -> Result<Tensor> {
        panic!("poisoned replica (test)");
    }
    fn in_features(&self) -> usize {
        4
    }
}

#[test]
fn lost_replica_slot_degrades_but_the_server_keeps_serving() {
    // Worker 0's replica crashes on its first forward and its slot can
    // never rebuild (the factory refuses); worker 1 carries the load.
    let built_once = Arc::new(AtomicBool::new(false));
    let flag = built_once.clone();
    let inner = Arc::new(mlp_factory(4));
    let factory = FactoryFn::new(4, move |worker| {
        if worker == 0 {
            if flag.swap(true, Ordering::SeqCst) {
                return Err(Error::msg("slot 0 cannot rebuild"));
            }
            let m: Box<dyn BatchModel> = Box::new(AlwaysCrash);
            Ok(m)
        } else {
            inner.build(worker)
        }
    });
    let cfg = ServeConfig::new()
        .workers(2)
        .max_batch(1)
        .max_wait_ms(0)
        .restart_limit(2)
        .restart_backoff_ms(1)
        .build()
        .unwrap();
    let server = InferenceServer::start(factory, cfg).unwrap();

    // Keep submitting until worker 0 eats one; every reply is definite
    // (Ok from worker 1, or WorkerCrashed from worker 0) — never a hang.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.stats().worker_crashes == 0 && Instant::now() < deadline {
        let _ = server.infer(vec![0.1; 4]);
    }
    assert!(server.stats().worker_crashes >= 1, "worker 0 never crashed");

    // Both rebuild attempts fail → the slot is abandoned → degraded.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.stats().health != "degraded" && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = server.stats();
    assert_eq!(stats.health, "degraded");
    assert_eq!(stats.workers_alive, 1);
    assert_eq!(stats.worker_restarts, 0, "no rebuild can succeed here");

    // …but the surviving replica keeps answering.
    for _ in 0..8 {
        assert_eq!(server.infer(vec![0.2; 4]).unwrap().len(), 4);
    }
    server.shutdown();
}
