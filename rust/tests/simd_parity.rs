//! SIMD ↔ scalar parity suite: every op family must produce **bitwise**
//! identical results with the vector path on and off
//! (`simd::set_simd_enabled`, the programmatic twin of
//! `MINITENSOR_SIMD=off`), at 1 and at 4 worker threads. This is the
//! determinism contract the library documents: scalar ≡ SIMD ≡ any
//! thread count, bit for bit — vectorization is observable only in
//! speed, never in results.
//!
//! Both knobs are process-global, so every test serializes on one lock
//! and restores the entry state on exit (the same discipline as the
//! thread-flipping properties in `proptests.rs`).

use minitensor::autograd::{gradcheck, Var};
use minitensor::data::Rng;
use minitensor::ops::softmax::softmax_scaled_lastdim;
use minitensor::runtime::{parallel, simd};
use minitensor::tensor::Tensor;

/// Serialize tests that flip the process-global SIMD path / thread count.
fn knob_lock() -> std::sync::MutexGuard<'static, ()> {
    use std::sync::{Mutex, OnceLock};
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Saved knob state, restored on drop so a failing assert can't leak a
/// scalar path or 4-thread setting into the next test.
struct KnobGuard {
    _lock: std::sync::MutexGuard<'static, ()>,
    threads: usize,
    vector: bool,
}

impl KnobGuard {
    fn new() -> KnobGuard {
        let lock = knob_lock();
        KnobGuard {
            _lock: lock,
            threads: parallel::num_threads(),
            vector: simd::path().is_vector(),
        }
    }
}

impl Drop for KnobGuard {
    fn drop(&mut self) {
        parallel::set_num_threads(self.threads);
        simd::set_simd_enabled(self.vector);
    }
}

fn assert_bits_eq(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.dims(), b.dims(), "{ctx}: shape");
    let (av, bv) = (a.to_vec(), b.to_vec());
    for i in 0..av.len() {
        assert_eq!(
            av[i].to_bits(),
            bv[i].to_bits(),
            "{ctx}: elem {i} ({} vs {})",
            av[i],
            bv[i]
        );
    }
}

/// Run `f` with SIMD forced off at 1 thread as the reference, then
/// assert the same computation is bitwise-equal with SIMD on and off at
/// 1, 2, and 4 threads. On hosts without AVX2/NEON the "on" legs
/// re-resolve to scalar and the check degenerates to thread invariance,
/// which is still a real property.
fn parity<F: Fn() -> Tensor>(ctx: &str, f: F) {
    simd::set_simd_enabled(false);
    parallel::set_num_threads(1);
    let reference = f();
    for on in [false, true] {
        simd::set_simd_enabled(on);
        for threads in [1usize, 2, 4] {
            parallel::set_num_threads(threads);
            let got = f();
            assert_bits_eq(&reference, &got, &format!("{ctx} simd={on} t={threads}"));
        }
    }
}

/// Lengths that exercise full 8-lane blocks, the scalar tail, and the
/// empty edge.
const LENS: [usize; 5] = [1, 7, 8, 65, 1000];

#[test]
fn elementwise_binary_parity() {
    let _g = KnobGuard::new();
    let mut rng = Rng::new(300);
    for &n in &LENS {
        let a = Tensor::randn(&[n], 0.0, 2.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 2.0, &mut rng);
        parity(&format!("add n={n}"), || a.add(&b).unwrap());
        parity(&format!("sub n={n}"), || a.sub(&b).unwrap());
        parity(&format!("mul n={n}"), || a.mul(&b).unwrap());
        parity(&format!("div n={n}"), || a.div(&b).unwrap());
        parity(&format!("maximum n={n}"), || a.maximum(&b).unwrap());
        parity(&format!("minimum n={n}"), || a.minimum(&b).unwrap());
    }
}

#[test]
fn elementwise_broadcast_and_strided_parity() {
    let _g = KnobGuard::new();
    let mut rng = Rng::new(301);
    // Tier 2: matrix + row vector (the bias pattern).
    for &(r, c) in &[(3usize, 5usize), (16, 8), (7, 33)] {
        let x = Tensor::randn(&[r, c], 0.0, 1.0, &mut rng);
        let v = Tensor::randn(&[c], 0.0, 1.0, &mut rng);
        parity(&format!("bias add {r}x{c}"), || x.add(&v).unwrap());
        parity(&format!("bias mul {r}x{c}"), || x.mul(&v).unwrap());
    }
    // Strided fallback: transposed (non-contiguous) views must agree
    // with the vector tiers because the scalar twins are the same
    // per-element functions.
    let x = Tensor::randn(&[9, 11], 0.0, 1.0, &mut rng);
    let y = Tensor::randn(&[11, 9], 0.0, 1.0, &mut rng);
    let yt = y.t().unwrap();
    parity("strided add", || x.add(&yt).unwrap());
    parity("strided vs contiguous", || {
        let a = x.add(&yt).unwrap();
        let b = x.add(&yt.contiguous()).unwrap();
        assert_bits_eq(&a, &b, "strided == materialized");
        a
    });
    // Ternary select through the composed dispatcher.
    let c = Tensor::randn(&[9, 11], 0.0, 1.0, &mut rng).gt(&x).unwrap();
    parity("where_cond", || x.where_cond(&c, &y.t().unwrap()).unwrap());
}

#[test]
fn transcendental_unary_parity() {
    let _g = KnobGuard::new();
    let mut rng = Rng::new(302);
    for &n in &LENS {
        let x = Tensor::randn(&[n], 0.0, 3.0, &mut rng);
        parity(&format!("neg n={n}"), || x.neg());
        parity(&format!("abs n={n}"), || x.abs());
        parity(&format!("square n={n}"), || x.square());
        parity(&format!("relu n={n}"), || x.relu());
        parity(&format!("leaky n={n}"), || x.leaky_relu(0.1));
        parity(&format!("clamp n={n}"), || x.clamp(-0.75, 1.25));
        parity(&format!("adds n={n}"), || x.add_scalar(0.37));
        parity(&format!("muls n={n}"), || x.mul_scalar(-1.61));
        parity(&format!("exp n={n}"), || x.exp());
        parity(&format!("tanh n={n}"), || x.tanh());
        parity(&format!("sigmoid n={n}"), || x.sigmoid());
        parity(&format!("gelu n={n}"), || x.gelu());
        // sqrt: non-negative inputs only — for negative inputs the
        // different paths may return NaNs with different payload bits.
        let nn = x.abs();
        parity(&format!("sqrt n={n}"), || nn.sqrt());
    }
    // Saturation ranges of the polynomial kernels.
    let extreme = Tensor::from_vec(
        vec![-1.0e4, -90.0, -20.0, -0.625, 0.0, 0.625, 20.0, 90.0, 1.0e4],
        &[9],
    )
    .unwrap();
    parity("exp extreme", || extreme.exp());
    parity("tanh extreme", || extreme.tanh());
    parity("sigmoid extreme", || extreme.sigmoid());
}

#[test]
fn fused_tape_parity() {
    let _g = KnobGuard::new();
    let mut rng = Rng::new(303);
    for &n in &[64usize, 1000] {
        let a = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[n], 0.0, 1.0, &mut rng);
        // Multi-op fused region: (a*b + a).relu().tanh() — one tape, the
        // interpreter runs every instruction over 8-lane blocks.
        parity(&format!("fused tape n={n}"), || {
            let (la, lb) = (a.lazy(), b.lazy());
            la.mul(&lb)
                .unwrap()
                .add(&la)
                .unwrap()
                .relu()
                .tanh()
                .eval()
                .unwrap()
        });
        // Fused region with a sum epilogue (scalar result).
        parity(&format!("fused sum n={n}"), || {
            let (la, lb) = (a.lazy(), b.lazy());
            la.mul(&lb).unwrap().exp().sum().eval().unwrap()
        });
    }
    // Fused where + axis-reduce epilogue over rows.
    let x = Tensor::randn(&[17, 33], 0.0, 1.0, &mut rng);
    let y = Tensor::randn(&[17, 33], 0.0, 1.0, &mut rng);
    let c = x.gt(&y).unwrap();
    parity("fused where+rowsum", || {
        let l = x
            .lazy()
            .mul(&y.lazy())
            .unwrap()
            .where_cond(&c.lazy(), &y.lazy())
            .unwrap();
        l.sum_axis(-1, false).unwrap().eval().unwrap()
    });
}

#[test]
fn row_softmax_parity() {
    let _g = KnobGuard::new();
    let mut rng = Rng::new(304);
    for &(r, c) in &[(1usize, 1usize), (4, 7), (8, 8), (13, 65), (3, 1000)] {
        let t = Tensor::randn(&[r, c], 0.0, 3.0, &mut rng);
        parity(&format!("softmax {r}x{c}"), || t.softmax().unwrap());
        parity(&format!("log_softmax {r}x{c}"), || {
            t.log_softmax().unwrap()
        });
        parity(&format!("softmax_scaled {r}x{c}"), || {
            softmax_scaled_lastdim(&t, 0.125).unwrap()
        });
        // The PR 5 fusion pin must keep holding under every path.
        parity(&format!("scaled==unfused {r}x{c}"), || {
            let fused = softmax_scaled_lastdim(&t, 0.25).unwrap();
            let eager = t.mul_scalar(0.25).softmax().unwrap();
            assert_bits_eq(&fused, &eager, "softmax_scaled pin");
            fused
        });
    }
}

#[test]
fn sgemm_parity() {
    let _g = KnobGuard::new();
    let mut rng = Rng::new(305);
    // Shapes straddling the naive-path threshold and the MR/NR edges:
    // ragged rows (m % 4 != 0), ragged columns (n % 16 != 0), and a
    // k that spans multiple packed panels.
    for &(m, k, n) in &[
        (4usize, 8usize, 16usize),
        (70, 60, 100),
        (64, 130, 96),
        (33, 65, 49),
    ] {
        let a = Tensor::randn(&[m, k], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 0.0, 1.0, &mut rng);
        parity(&format!("sgemm {m}x{k}x{n}"), || a.matmul(&b).unwrap());
    }
    // Batched path.
    let a = Tensor::randn(&[3, 20, 70], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[3, 70, 40], 0.0, 1.0, &mut rng);
    parity("batched sgemm", || a.matmul(&b).unwrap());
}

#[test]
fn gradcheck_through_simd_matmul() {
    // Finite differences vs autograd through a matmul big enough to hit
    // the blocked SGEMM (m·k·n > 64³) with the vector path active.
    let _g = KnobGuard::new();
    simd::set_simd_enabled(true);
    parallel::set_num_threads(2);
    let mut rng = Rng::new(306);
    let w = Tensor::randn(&[24, 512], 0.0, 0.3, &mut rng);
    let x0 = Tensor::randn(&[24, 24], 0.0, 0.5, &mut rng);
    let report = gradcheck(
        move |v: &Var| {
            let w = Var::from_tensor(w.clone(), false);
            v.matmul(&w)?.tanh().sum()
        },
        &x0,
        1e-2,
        2e-2,
    )
    .unwrap();
    assert!(report.pass, "{report:?}");
}
