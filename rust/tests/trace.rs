//! The tracing subsystem end to end: ring-buffer overwrite semantics,
//! span nesting and thread attribution, and the Chrome-trace JSON
//! export (validated with a small hand-rolled JSON parser — the crate
//! stays zero-dependency even in tests).
//!
//! Tracing state is process-global (one enable flag, one ring
//! registry), so every test serializes on [`guard`] and clears the
//! rings on entry and exit.

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use minitensor::coordinator::{InferenceServer, NativeModelFactory, ServeConfig};
use minitensor::data::Rng;
use minitensor::nn::{Activation, Dense, Sequential};
use minitensor::runtime::{parallel, trace};
use minitensor::tensor::Tensor;

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn disabled_tracing_records_nothing() {
    let _g = guard();
    trace::disable();
    trace::clear();
    {
        let mut sp = trace::span("test", "invisible");
        sp.arg_u("n", 1);
    }
    trace::record_interval(
        0,
        "test",
        "also_invisible",
        std::time::Instant::now(),
        std::time::Instant::now(),
        &[],
    );
    assert!(trace::events().is_empty());
    assert_eq!(trace::dropped(), 0);
}

#[test]
fn span_nesting_and_thread_attribution() {
    let _g = guard();
    trace::clear();
    trace::enable();

    let t1 = std::thread::spawn(|| {
        let _outer = trace::span("test", "outer");
        std::thread::sleep(Duration::from_millis(2));
        {
            let _inner = trace::span("test", "inner");
            std::thread::sleep(Duration::from_millis(2));
        }
        std::thread::sleep(Duration::from_millis(1));
    });
    t1.join().unwrap();
    let t2 = std::thread::spawn(|| {
        let _sp = trace::span("test", "elsewhere");
    });
    t2.join().unwrap();
    trace::disable();

    let evs = trace::events();
    let find = |name: &str| {
        *evs.iter()
            .find(|e| e.name == name)
            .unwrap_or_else(|| panic!("span '{name}' not recorded"))
    };
    let (outer, inner, elsewhere) = (find("outer"), find("inner"), find("elsewhere"));

    // The inner span nests strictly within the outer span's bounds.
    assert!(inner.t0_ns >= outer.t0_ns, "{inner:?} vs {outer:?}");
    assert!(
        inner.t0_ns + inner.dur_ns <= outer.t0_ns + outer.dur_ns,
        "{inner:?} vs {outer:?}"
    );
    assert!(inner.dur_ns < outer.dur_ns);

    // Same thread → same track; different thread → different track.
    assert_eq!(inner.track, outer.track);
    assert_ne!(elsewhere.track, outer.track);
    let names = trace::track_names();
    for t in [outer.track, elsewhere.track] {
        assert!(names.iter().any(|&(id, _)| id == t), "track {t} unnamed");
    }
    trace::clear();
}

#[test]
fn ring_overwrites_oldest_and_counts_drops() {
    let _g = guard();
    trace::clear();
    trace::set_ring_capacity(8);
    trace::enable();

    // A fresh thread gets a fresh ring sized by the capacity above.
    std::thread::spawn(|| {
        for i in 0..20u64 {
            let mut sp = trace::span("test", "ring");
            sp.arg_u("i", i);
        }
    })
    .join()
    .unwrap();
    trace::disable();

    let kept: Vec<u64> = trace::events()
        .into_iter()
        .filter(|e| e.name == "ring")
        .map(|e| match e.args[0] {
            ("i", trace::ArgVal::U(v)) => v,
            other => panic!("unexpected arg {other:?}"),
        })
        .collect();
    // Capacity 8: the 8 newest survive, oldest-first, 12 are dropped.
    assert_eq!(kept, (12..20).collect::<Vec<u64>>());
    assert_eq!(trace::dropped(), 12);

    // The drop count travels into both human and machine outputs: the
    // Chrome export carries it as otherData metadata, and the summary
    // states it so truncated traces are never mistaken for complete.
    let doc = json::parse(&trace::chrome_trace_json()).expect("valid JSON");
    assert_eq!(
        doc.get("otherData")
            .and_then(|o| o.get("droppedSpans"))
            .and_then(json::Value::as_f64),
        Some(12.0)
    );
    assert_eq!(
        doc.get("otherData")
            .and_then(|o| o.get("ringCapacity"))
            .and_then(json::Value::as_f64),
        Some(8.0)
    );
    let summary = trace::summary();
    assert!(summary.contains("12 overwritten"), "{summary}");

    trace::clear();
    trace::set_ring_capacity(trace::DEFAULT_RING_CAPACITY);
}

#[test]
fn chrome_trace_is_valid_json_spanning_all_subsystems() {
    let _g = guard();
    trace::clear();
    let before_threads = parallel::num_threads();
    parallel::set_num_threads(2);
    trace::enable();

    // exec + parallel + graph: a fused lazy chain big enough to engage
    // the worker pool (65536 elems × 3 ops ≫ the parallel threshold).
    let mut rng = Rng::new(3);
    let a = Tensor::randn(&[1 << 16], 0.0, 1.0, &mut rng);
    let b = Tensor::randn(&[1 << 16], 0.0, 1.0, &mut rng);
    for _ in 0..3 {
        let out = a
            .lazy()
            .mul(&b.lazy())
            .unwrap()
            .add(&a.lazy())
            .unwrap()
            .relu()
            .eval()
            .unwrap();
        assert_eq!(out.numel(), 1 << 16);
    }

    // serve: a tiny server answering a handful of requests.
    let factory = NativeModelFactory::new(4, || {
        let mut rng = Rng::new(1);
        Sequential::new()
            .add(Dense::new(4, 8, &mut rng))
            .add(Activation::Relu)
            .add(Dense::new(8, 3, &mut rng))
    });
    let server = InferenceServer::start(factory, ServeConfig::default()).unwrap();
    for i in 0..4 {
        assert_eq!(server.infer(vec![i as f32, 0.0, 0.0, 0.0]).unwrap().len(), 3);
    }
    let stats = server.stats();
    assert!(stats.exec_dispatches > 0);
    server.shutdown();

    trace::disable();
    parallel::set_num_threads(before_threads);

    let text = trace::chrome_trace_json();
    let doc = json::parse(&text).expect("export must be valid JSON");
    // Metadata header: drop count (0 here) and ring capacity always ride
    // along so consumers can detect truncated traces.
    let other = doc.get("otherData").expect("otherData metadata");
    assert!(other.get("droppedSpans").and_then(json::Value::as_f64).is_some());
    assert!(other.get("ringCapacity").and_then(json::Value::as_f64).unwrap_or(0.0) >= 8.0);
    let events = doc
        .get("traceEvents")
        .and_then(json::Value::as_arr)
        .expect("traceEvents array");

    let spans: Vec<&json::Value> = events
        .iter()
        .filter(|e| e.get("ph").and_then(json::Value::as_str) == Some("X"))
        .collect();
    assert!(!spans.is_empty());
    for want in ["exec", "parallel", "graph", "serve"] {
        assert!(
            spans
                .iter()
                .any(|e| e.get("cat").and_then(json::Value::as_str) == Some(want)),
            "no '{want}' spans in the trace"
        );
    }
    // Every span carries numeric µs timestamps on a named track.
    for e in &spans {
        assert!(e.get("ts").and_then(json::Value::as_f64).is_some(), "{e:?}");
        assert!(e.get("dur").and_then(json::Value::as_f64).unwrap_or(-1.0) >= 0.0);
        assert!(e.get("tid").and_then(json::Value::as_f64).is_some());
    }
    // Dispatch spans keep their element-count args through the export.
    assert!(spans.iter().any(|e| {
        e.get("cat").and_then(json::Value::as_str) == Some("exec")
            && e.get("args").and_then(|a| a.get("elems")).is_some()
    }));
    // The per-request virtual track is present and named in metadata.
    assert!(events.iter().any(|e| {
        let track = e
            .get("args")
            .and_then(|a| a.get("name"))
            .and_then(json::Value::as_str);
        e.get("ph").and_then(json::Value::as_str) == Some("M")
            && e.get("name").and_then(json::Value::as_str) == Some("thread_name")
            && track == Some("serve.requests")
    }));
    // And the request spans carry the queue/compute breakdown.
    assert!(spans.iter().any(|e| {
        e.get("cat").and_then(json::Value::as_str) == Some("serve")
            && e.get("name").and_then(json::Value::as_str) == Some("request")
            && e.get("args").and_then(|a| a.get("queue_us")).is_some()
            && e.get("args").and_then(|a| a.get("compute_us")).is_some()
    }));

    let summary = trace::summary();
    assert!(summary.contains("spans across"), "{summary}");
    assert!(summary.contains("exec."), "{summary}");
    trace::clear();
}

/// Minimal recursive-descent JSON parser — enough to validate the
/// trace export without pulling in a dependency.
mod json {
    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(Vec<(String, Value)>),
    }

    impl Value {
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }
        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }
        pub fn as_arr(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(v) => Some(v),
                _ => None,
            }
        }
    }

    pub fn parse(s: &str) -> Result<Value, String> {
        let b = s.as_bytes();
        let mut p = 0;
        let v = value(b, &mut p)?;
        skip_ws(b, &mut p);
        if p != b.len() {
            return Err(format!("trailing data at byte {p}"));
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], p: &mut usize) {
        while *p < b.len() && matches!(b[*p], b' ' | b'\t' | b'\n' | b'\r') {
            *p += 1;
        }
    }

    fn expect(b: &[u8], p: &mut usize, c: u8) -> Result<(), String> {
        if *p < b.len() && b[*p] == c {
            *p += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, *p))
        }
    }

    fn value(b: &[u8], p: &mut usize) -> Result<Value, String> {
        skip_ws(b, p);
        match b.get(*p) {
            Some(b'{') => object(b, p),
            Some(b'[') => array(b, p),
            Some(b'"') => Ok(Value::Str(string(b, p)?)),
            Some(b't') => lit(b, p, "true", Value::Bool(true)),
            Some(b'f') => lit(b, p, "false", Value::Bool(false)),
            Some(b'n') => lit(b, p, "null", Value::Null),
            Some(_) => number(b, p),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(b: &[u8], p: &mut usize, word: &str, v: Value) -> Result<Value, String> {
        if b[*p..].starts_with(word.as_bytes()) {
            *p += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", *p))
        }
    }

    fn object(b: &[u8], p: &mut usize) -> Result<Value, String> {
        expect(b, p, b'{')?;
        let mut kv = Vec::new();
        skip_ws(b, p);
        if b.get(*p) == Some(&b'}') {
            *p += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            skip_ws(b, p);
            let k = string(b, p)?;
            skip_ws(b, p);
            expect(b, p, b':')?;
            kv.push((k, value(b, p)?));
            skip_ws(b, p);
            match b.get(*p) {
                Some(b',') => *p += 1,
                Some(b'}') => {
                    *p += 1;
                    return Ok(Value::Obj(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", *p)),
            }
        }
    }

    fn array(b: &[u8], p: &mut usize) -> Result<Value, String> {
        expect(b, p, b'[')?;
        let mut out = Vec::new();
        skip_ws(b, p);
        if b.get(*p) == Some(&b']') {
            *p += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(value(b, p)?);
            skip_ws(b, p);
            match b.get(*p) {
                Some(b',') => *p += 1,
                Some(b']') => {
                    *p += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", *p)),
            }
        }
    }

    fn string(b: &[u8], p: &mut usize) -> Result<String, String> {
        expect(b, p, b'"')?;
        let mut out = String::new();
        while let Some(&c) = b.get(*p) {
            *p += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *b.get(*p).ok_or("unterminated escape")?;
                    *p += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = b
                                .get(*p..*p + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("bad \\u escape")?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            *p += 4;
                            out.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                _ => {
                    // Multi-byte UTF-8: copy the full code point.
                    let start = *p - 1;
                    let width = utf8_width(c);
                    *p = start + width;
                    let s = b
                        .get(start..*p)
                        .and_then(|s| std::str::from_utf8(s).ok())
                        .ok_or("invalid UTF-8 in string")?;
                    out.push_str(s);
                }
            }
        }
        Err("unterminated string".into())
    }

    fn utf8_width(first: u8) -> usize {
        match first {
            0x00..=0x7f => 1,
            0xc0..=0xdf => 2,
            0xe0..=0xef => 3,
            _ => 4,
        }
    }

    fn number(b: &[u8], p: &mut usize) -> Result<Value, String> {
        let start = *p;
        while *p < b.len() && matches!(b[*p], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
            *p += 1;
        }
        std::str::from_utf8(&b[start..*p])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}
