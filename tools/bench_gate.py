#!/usr/bin/env python3
"""CI perf-regression gate over the bench trajectory files.

Compares a fresh `--quick` bench run against a committed baseline:

    python3 tools/bench_gate.py --baseline /tmp/BENCH_fusion.baseline.json \
                                --fresh BENCH_fusion.json

Rows are matched by their sweep identity (experiment axes only — host
facts like the detected SIMD path or core count are deliberately NOT
part of the key, so a baseline recorded on an AVX2 box still gates a
scalar CI runner). Per-unit metrics are compared with a generous
tolerance: quick-mode windows are short and CI machines are noisy, so
the gate is a tripwire for 2x-class regressions, not a 5% detector.

Rules:
  * lower-is-better ns metrics: fresh must be <= TOLERANCE x baseline;
  * higher-is-better req_per_s: fresh must be >= baseline / TOLERANCE;
  * serve rows whose worker count exceeds the fresh host's cores are
    skipped (an oversubscribed sweep point measures the scheduler);
  * at least one row must match, otherwise the gate itself is broken
    (schema drift) and fails loudly.

Exit status: 0 = pass, 1 = regression, 2 = usage/schema error.
"""

import argparse
import json
import sys

TOLERANCE = 2.0

# Sweep-identity keys and gated per-unit metrics, by experiment kind.
# Metrics ending in req_per_s are higher-is-better; the rest are
# lower-is-better nanosecond costs. Kinds absent here (equivalence
# checks, footprint rows) are correctness-tested elsewhere and skipped.
KINDS = {
    "fusion": {
        "key": ("chain", "n", "threads"),
        "metrics": ("eager_ns_per_elem", "fused_ns_per_elem"),
    },
    "fusion_cache": {
        "key": ("n", "threads"),
        "metrics": ("cold_eval_ns", "cached_eval_ns"),
    },
    "softmax_fused": {
        "key": ("n", "threads"),
        "metrics": ("eager_ns_per_row", "fused_ns_per_row"),
    },
    "simd_onoff": {
        "key": ("kernel", "n", "threads"),
        "metrics": ("on_ns", "off_ns"),
    },
    "serve_sweep": {
        "key": ("workers", "max_batch", "clients"),
        "metrics": ("p50_ms", "p95_ms", "p99_ms", "req_per_s"),
    },
}

HIGHER_IS_BETTER = {"req_per_s"}


def load_rows(path):
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"bench_gate: cannot read {path}: {e}")
    if not isinstance(rows, list):
        sys.exit(f"bench_gate: {path}: expected a JSON array of rows")
    return rows


def identity(row):
    kind = row.get("bench")
    spec = KINDS.get(kind)
    if spec is None:
        return None
    try:
        return (kind,) + tuple(row[k] for k in spec["key"])
    except KeyError as e:
        sys.exit(f"bench_gate: row {row} missing identity key {e}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed trajectory JSON")
    ap.add_argument("--fresh", required=True, help="just-produced --quick run")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=TOLERANCE,
        help=f"allowed slowdown factor (default {TOLERANCE})",
    )
    args = ap.parse_args()

    base = {}
    for row in load_rows(args.baseline):
        ident = identity(row)
        if ident is not None:
            base[ident] = row

    matched = 0
    failures = []
    for row in load_rows(args.fresh):
        ident = identity(row)
        if ident is None or ident not in base:
            continue
        kind = ident[0]
        if kind == "serve_sweep" and row.get("workers", 1) > row.get("cores", 1):
            # Oversubscribed on this host: latency measures contention,
            # not the serving stack. The baseline host had enough cores.
            print(f"skip  {ident}: {row['workers']} workers > {row['cores']} cores")
            continue
        ref = base[ident]
        matched += 1
        for metric in KINDS[kind]["metrics"]:
            if metric not in row or metric not in ref:
                failures.append(f"{ident}: metric '{metric}' missing")
                continue
            fresh_v, base_v = float(row[metric]), float(ref[metric])
            if base_v <= 0:
                continue  # degenerate baseline sample: nothing to gate
            if metric in HIGHER_IS_BETTER:
                ok = fresh_v >= base_v / args.tolerance
                verdict = f"{fresh_v:.0f} vs baseline {base_v:.0f} (floor {base_v / args.tolerance:.0f})"
            else:
                ok = fresh_v <= base_v * args.tolerance
                verdict = f"{fresh_v:.1f} vs baseline {base_v:.1f} (ceiling {base_v * args.tolerance:.1f})"
            line = f"{ident} {metric}: {verdict}"
            if ok:
                print(f"ok    {line}")
            else:
                print(f"FAIL  {line}")
                failures.append(line)

    if matched == 0:
        sys.exit(
            "bench_gate: no rows matched between baseline and fresh run — "
            "schema drift? Update KINDS in tools/bench_gate.py alongside the bench."
        )
    if failures:
        print(f"\nbench_gate: {len(failures)} regression(s) beyond {args.tolerance}x:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print(f"\nbench_gate: {matched} row(s) within {args.tolerance}x of baseline")


if __name__ == "__main__":
    main()
