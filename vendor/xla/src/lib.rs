//! No-op stub of the `xla` PJRT binding crate (see `Cargo.toml` for why).
//!
//! Two layers:
//!
//! - [`Literal`] is **functional**: a host-side f32 buffer with shape
//!   metadata, enough for `Tensor ⇄ Literal` conversion code and its unit
//!   tests to work unchanged.
//! - The PJRT plane ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`], [`HloModuleProto`], [`XlaComputation`]) type-checks
//!   but every constructor/executor returns [`Error`], so callers hit
//!   their existing "no runtime available" fallbacks instead of UB.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the real crate's: stringly, `Display`-able.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real PJRT runtime; this build links the \
         vendored no-op stub (patch in the actual `xla` crate to run AOT \
         artifacts)"
    ))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    /// Convert from the stub's f32 backing store.
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// Host-side tensor literal: flat f32 data plus dimensions.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal {
            data: values.to_vec(),
            dims: vec![values.len() as i64],
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Dimensions of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the data out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Destructure a tuple literal. The stub never produces tuples (only
    /// [`PjRtBuffer::to_literal_sync`] would, and it always errors), so
    /// reaching this on a non-tuple is a stub-usage error.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module proto (opaque in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. Always errors in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation handle (opaque in the stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed proto. Constructible only from [`HloModuleProto`],
    /// which the stub never yields.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (opaque in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (opaque in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments. Always errors in the stub.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (opaque in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Construct a CPU client. Always errors in the stub, which is what
    /// routes `Engine::cpu` callers to their graceful skip paths.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the backing runtime.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Always errors in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.element_count(), 6);
        let m = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        let back: Vec<f32> = m.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[7]).is_err());
        // rank-0 reshape of a single element is legal (scalar literals).
        let s = Literal::vec1(&[7.5]).reshape(&[]).unwrap();
        assert_eq!(s.element_count(), 1);
    }

    #[test]
    fn pjrt_plane_errors_descriptively() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
